"""Seeded fault injection for the training path (the train-side
mirror of ``serve.ChaosConfig`` / ``serve.fleet.FleetChaosConfig``).

:class:`TrainChaosConfig` describes WHAT can go wrong; every decision
is a pure function of ``(seed, kind, index)`` (hash-keyed
``np.random.default_rng``, no shared stream), so a fault schedule is
reproducible regardless of how many times the trainer crashes and
replays the surrounding steps. :class:`ChaosState` carries the
cross-incarnation bookkeeping (fired sets, blast-radius caps, audit
counts) and is owned by the HARNESS — it survives the simulated
process crashes that destroy the Trainer itself.

Fault kinds
-----------

=================  =====================================================
loss spike         the OBSERVED loss for a batch is multiplied by
                   ``spike_scale`` before the divergence detector sees
                   it (keyed on the batch index, so the PaLM-style
                   batch-window skip after a rollback retires the fault)
process crash      :class:`SimulatedCrash` raised after a step's
                   bookkeeping but BEFORE its checkpoint save — the
                   worst case: everything since the last checkpoint is
                   lost and must replay bit-identically on resume
preemption         the cooperative :class:`~repro.training.train_loop.
                   PreemptionSignal` fires (save + clean exit; the
                   harness restarts and the run resumes)
transient IO       the CheckpointManager ``fault_hook`` raises on a
                   store op's FIRST attempt only — always succeeds
                   within the manager's retry budget (PR 8 path)
corrupt store      a just-COMMITted checkpoint's first leaf file is
                   truncated in place — the next restore must fall back
                   to the last known-good step (PR 6 path)
=================  =====================================================

:func:`run_chaotic` is the save/teardown/rebuild driver: it builds a
fresh Trainer after every crash/preemption (the caller's
``make_trainer`` must create a NEW ``PreemptionSignal`` and data
iterator each time — exactly what a restarted process would do) and
returns the completed run plus the chaos ledger.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np


class SimulatedCrash(RuntimeError):
    """Injected process death: the trainer vanishes mid-interval with
    no final checkpoint; only ``run_chaotic`` may catch it."""


# Stable per-kind salts so decisions for different fault kinds at the
# same index never correlate.
_KIND_SALT = {"spike": 1, "crash": 2, "preempt": 3, "io": 4,
              "corrupt": 5}


@dataclasses.dataclass(frozen=True)
class TrainChaosConfig:
    seed: int = 0
    # Finite loss spikes, keyed on the BATCH index (DataIterator.step
    # of the consumed batch): deterministic list + seeded probability.
    spike_batches: tuple = ()
    spike_prob: float = 0.0
    spike_scale: float = 100.0
    max_spikes: int = 4
    # Simulated process crashes, keyed on the optimizer step that just
    # completed (fires before that step's checkpoint save).
    crash_steps: tuple = ()
    crash_prob: float = 0.0
    max_crashes: int = 2
    # Preemption storm: the cooperative SIGTERM path (save + exit).
    preempt_steps: tuple = ()
    preempt_prob: float = 0.0
    max_preempts: int = 2
    # Transient store IO faults (first attempt of an op fails; the
    # manager's capped-backoff retry path absorbs it).
    io_fault_prob: float = 0.0
    max_io_faults: int = 8
    # Corrupt-after-COMMIT store faults, keyed on the checkpoint step
    # (never fired on the step-0 rollback anchor).
    corrupt_steps: tuple = ()
    corrupt_prob: float = 0.0
    max_corrupts: int = 1
    # Audit trainer invariants every step (Trainer.audit).
    audit: bool = True


class ChaosState:
    """Harness-owned fault ledger, shared across Trainer incarnations."""

    def __init__(self, chaos: TrainChaosConfig):
        self.chaos = chaos
        self.spikes = 0
        self.crashes = 0
        self.preempts = 0
        self.io_faults = 0
        self.io_ops = 0
        self.corrupts = 0
        self.audits = 0
        self.rebuilds = 0
        self._fired: set = set()  # (kind, idx) for deterministic lists

    def _coin(self, kind: str, idx: int, prob: float) -> bool:
        if prob <= 0.0:
            return False
        rng = np.random.default_rng(
            (int(self.chaos.seed), _KIND_SALT[kind], int(idx)))
        return bool(rng.random() < prob)

    def _fire(self, kind: str, idx: int, listed: tuple, prob: float,
              count: int, cap: int) -> bool:
        if count >= cap:
            return False
        if idx in listed:
            # Deterministic faults fire once per harness lifetime —
            # a crash-replayed step must not re-raise the same fault
            # forever.
            if (kind, idx) in self._fired:
                return False
            self._fired.add((kind, idx))
            return True
        return self._coin(kind, idx, prob)

    # -- decision points (called by Trainer) ---------------------------
    def spike_at(self, batch_idx: int) -> bool:
        ch = self.chaos
        if self._fire("spike", batch_idx, ch.spike_batches,
                      ch.spike_prob, self.spikes, ch.max_spikes):
            self.spikes += 1
            return True
        return False

    def crash_at(self, step: int) -> bool:
        ch = self.chaos
        if self._fire("crash", step, ch.crash_steps, ch.crash_prob,
                      self.crashes, ch.max_crashes):
            self.crashes += 1
            return True
        return False

    def preempt_at(self, step: int) -> bool:
        ch = self.chaos
        if self._fire("preempt", step, ch.preempt_steps,
                      ch.preempt_prob, self.preempts, ch.max_preempts):
            self.preempts += 1
            return True
        return False

    def fault_hook(self, op: str, attempt: int) -> None:
        """CheckpointManager hook: transient-only — never fails a
        retry, so the op always lands within the retry budget."""
        if attempt > 0:
            return
        self.io_ops += 1
        if self.chaos.io_fault_prob <= 0.0 \
                or self.io_faults >= self.chaos.max_io_faults:
            return
        if self._coin("io", self.io_ops, self.chaos.io_fault_prob):
            self.io_faults += 1
            raise OSError(f"chaos: transient store fault ({op})")

    def maybe_corrupt(self, manager, step: int) -> bool:
        """Tear the just-written checkpoint's first leaf in place
        (COMMIT stays — the torn payload is only discovered at
        restore, which must fall back to an older step)."""
        ch = self.chaos
        if step <= 0:  # never corrupt the rollback anchor
            return False
        if not self._fire("corrupt", step, ch.corrupt_steps,
                          ch.corrupt_prob, self.corrupts,
                          ch.max_corrupts):
            return False
        from repro.checkpoint import store

        manager.wait()  # the async writer must finish first
        path = manager.step_path(step)
        leaves = store.leaf_files(path)
        if not leaves or not store.is_valid(path):
            return False
        with open(leaves[0], "wb") as f:
            f.write(b"\x93NUMPY")  # torn: magic only, no header/data
        self.corrupts += 1
        return True

    def summary(self) -> dict:
        return {
            "spikes": self.spikes, "crashes": self.crashes,
            "preempts": self.preempts, "io_faults": self.io_faults,
            "corrupts": self.corrupts, "audits": self.audits,
            "rebuilds": self.rebuilds,
        }


def run_chaotic(
    make_trainer: Callable[[TrainChaosConfig, ChaosState], "object"],
    num_steps: int,
    chaos: TrainChaosConfig,
    *,
    state: Optional[ChaosState] = None,
    max_rebuilds: int = 64,
) -> tuple[dict, ChaosState]:
    """Drive a Trainer to completion through injected crashes and
    preemptions: build, run, and on every :class:`SimulatedCrash` or
    preemption exit tear the whole Trainer down and rebuild it from
    scratch (auto-resume does the rest). Returns ``(out, chaos_state)``
    where ``out`` is the final ``Trainer.run`` result.
    """
    st = state if state is not None else ChaosState(chaos)
    for _ in range(max_rebuilds):
        tr = make_trainer(chaos, st)
        try:
            out = tr.run(num_steps)
        except SimulatedCrash:
            st.rebuilds += 1
            continue
        if tr.preemption and int(out["state"]["step"]) < num_steps:
            st.rebuilds += 1
            continue
        out = dict(out)
        out["chaos"] = st.summary()
        return out, st
    raise RuntimeError(
        f"train chaos harness wedged: {max_rebuilds} rebuilds without "
        f"completing {num_steps} steps ({st.summary()})"
    )

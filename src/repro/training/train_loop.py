"""Training loop: jitted train_step (grad accumulation, compression,
remat), checkpoint/auto-resume, preemption handling.

``make_train_step`` builds a pure (state, batch) -> (state, metrics)
function; distribution comes entirely from in/out shardings + the logical
constraints inside the model (GSPMD) — the same function serves 1 CPU
device and a 512-chip mesh.

``Trainer`` is the fault-tolerant driver: auto-resume from the newest
valid checkpoint, periodic async saves, a preemption hook that triggers a
final save + clean exit (the launcher restarts the job, which resumes),
and a step-time watchdog for straggler diagnosis.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataIterator
from repro.obs.tracker import NULL, Tracker
from repro.models import model_zoo as zoo
from repro.models import param as pm
from repro.optim.base import Optimizer, apply_updates, global_norm
from repro.sharding import ShardCtx, act
from repro.training import compression


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    grad_accum: int = 1
    compression: str = "none"  # none | bf16 | int8
    checkpoint_every: int = 100
    log_every: int = 10
    max_to_keep: int = 3
    # straggler watchdog: warn when a step takes > factor * median
    straggler_factor: float = 3.0
    # Non-finite loss guard: a NaN/inf loss or grad norm skips the
    # optimizer update (params/opt state/residual keep their old
    # values, the step counter still advances — MoE router blowups are
    # the classic upcycling fine-tune failure); the Trainer aborts with
    # a clear error after this many CONSECUTIVE skips. 0 disables the
    # guard entirely (step applies whatever it computed).
    max_consecutive_skips: int = 10


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    ac: zoo.ApplyCfg = zoo.ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
    tc: TrainConfig = TrainConfig(),
):
    """Returns train_step(state, batch) -> (state, metrics).

    Kernel implementations come from ``ac`` (ApplyCfg): the default
    "auto" resolves here — at step-build time, so the jitted step traces
    with a concrete choice — to the fused Pallas forward+backward kernels
    on TPU and the XLA einsum path on CPU.
    """
    ac = ac.resolve()

    def grads_of(params, batch):
        (loss, mets), grads = jax.value_and_grad(
            zoo.loss_fn, has_aux=True
        )(params, batch, cfg, ac=ac, ctx=ctx)
        return grads, mets

    def train_step(state, batch):
        params = state["params"]
        if tc.grad_accum > 1:
            def micro(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb)
                return (
                    jax.tree.map(jnp.add, g_acc, g),
                    jax.tree.map(jnp.add, m_acc, m),
                ), None

            def reshape(x):
                b = x.shape[0]
                return x.reshape(
                    (tc.grad_accum, b // tc.grad_accum) + x.shape[1:]
                )

            micro_batches = jax.tree.map(reshape, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            from repro.models.stack import zero_metrics

            m0 = dict(zero_metrics())
            m0.update(loss=jnp.zeros(()), ce=jnp.zeros(()))
            (grads, mets), _ = jax.lax.scan(
                micro, (g0, m0), micro_batches
            )
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
            mets = jax.tree.map(lambda m: m / tc.grad_accum, mets)
        else:
            grads, mets = grads_of(params, batch)

        if tc.compression != "none":
            grads, residual = compression.compress(
                grads, state["residual"], tc.compression
            )
        else:
            residual = state.get("residual")

        updates, opt_state = optimizer.update(
            grads, state["opt_state"], params
        )
        new_params = apply_updates(params, updates)
        mets = dict(mets)
        grad_norm = global_norm(grads)
        mets["grad_norm"] = grad_norm
        if tc.max_consecutive_skips > 0:
            # Non-finite guard: keep the OLD params/opt state/residual
            # when the loss or grad norm blew up — all inside the jitted
            # step (jnp.where), zero extra host syncs; the Trainer reads
            # mets["skipped"] off the metrics it already pulls.
            ok = jnp.isfinite(mets["loss"]) & jnp.isfinite(grad_norm)

            def pick(new, old):
                return jax.tree.map(
                    lambda a, b: jnp.where(ok, a, b), new, old
                )

            new_params = pick(new_params, params)
            opt_state = pick(opt_state, state["opt_state"])
            if residual is not None and "residual" in state:
                residual = pick(residual, state["residual"])
            mets["skipped"] = (~ok).astype(jnp.float32)
        else:
            mets["skipped"] = jnp.zeros((), jnp.float32)
        new_state = dict(state)
        new_state.update(
            params=new_params,
            opt_state=opt_state,
            # The step counter tracks consumed batches, so checkpoint /
            # resume bookkeeping is oblivious to skipped updates.
            step=state["step"] + 1,
        )
        if residual is not None:
            new_state["residual"] = residual
        return new_state, mets

    return train_step


def init_train_state(
    rng,
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    dtype=jnp.float32,
    tc: TrainConfig = TrainConfig(),
    params: Any = None,
):
    """params: optional pre-built plain-array tree (e.g. upcycled)."""
    if params is None:
        wrapped = zoo.init_params(rng, cfg, dtype=dtype)
        params, _ = pm.split(wrapped)
    state = {
        "params": params,
        "opt_state": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tc.compression != "none":
        state["residual"] = compression.init_residual(params)
    return state


def state_axes(cfg: ArchConfig, *, dtype=jnp.float32,
               tc: TrainConfig = TrainConfig()):
    """Logical-axes tree matching init_train_state's structure."""
    wrapped = jax.eval_shape(
        lambda: zoo.init_params(jax.random.PRNGKey(0), cfg, dtype=dtype)
    )
    vals, axes = pm.split(wrapped)
    opt_axes = {
        "step": "",
        "slots": _adafactor_slot_axes(axes, vals),
    }
    out = {"params": axes, "opt_state": opt_axes, "step": ""}
    if tc.compression != "none":
        out["residual"] = axes
    return out


def _adafactor_slot_axes(axes_tree, shapes_tree):
    """Map param logical axes -> adafactor slot axes ({v_row, v_col} or
    {v}); mirrors optim/adafactor._factored exactly."""
    from repro.optim.adafactor import _factored

    def one(a: str, shaped):
        names = a.split() if a else []
        if _factored(tuple(shaped.shape)):
            return {
                "v_row": " ".join(names[:-1]),
                "v_col": " ".join(names[:-2] + names[-1:]),
            }
        return {"v": a}

    return jax.tree.map(one, axes_tree, shapes_tree)


class PreemptionSignal:
    """Cooperative preemption flag (SIGTERM handler or test hook)."""

    def __init__(self):
        self._flag = False

    def install(self):
        import signal

        def handler(signum, frame):
            self._flag = True

        signal.signal(signal.SIGTERM, handler)
        return self

    def trigger(self):
        self._flag = True

    def __bool__(self):
        return self._flag


@dataclasses.dataclass
class Trainer:
    cfg: ArchConfig
    optimizer: Optimizer
    data: DataIterator
    ckpt_dir: str
    ac: zoo.ApplyCfg = zoo.ApplyCfg()
    ctx: Optional[ShardCtx] = None
    tc: TrainConfig = TrainConfig()
    preemption: Optional[PreemptionSignal] = None
    log_fn: Callable[[str], None] = print
    # Observability: one "train" row per step (loss / ce / grad_norm /
    # skipped_steps / step_ms) plus checkpoint retry/fallback counters
    # — log_fn keeps the old print-style behaviour alongside.
    tracker: Optional[Tracker] = None

    def __post_init__(self):
        self.trk = self.tracker if self.tracker is not None else NULL
        self.manager = CheckpointManager(
            self.ckpt_dir, max_to_keep=self.tc.max_to_keep,
            tracker=self.trk,
        )
        self._step_times: list[float] = []

    def run(self, num_steps: int, *, rng=None, init_params=None) -> dict:
        rng = jax.random.PRNGKey(0) if rng is None else rng
        state = init_train_state(
            rng, self.cfg, self.optimizer, tc=self.tc, params=init_params
        )
        # ---- auto-resume -------------------------------------------------
        restored, step0, meta = self.manager.restore_latest(state)
        if restored is not None:
            state = restored
            self.data.restore(meta.get("data", {"step": step0}))
            self.log_fn(f"[trainer] resumed from step {step0}")
        train_step = jax.jit(
            make_train_step(
                self.cfg, self.optimizer, ac=self.ac, ctx=self.ctx,
                tc=self.tc,
            ),
            donate_argnums=(0,),
        )
        mets = {}
        start_step = int(state["step"])
        skipped_steps = 0
        consecutive_skips = 0
        for i in range(start_step, num_steps):
            batch = next(self.data)
            t0 = time.perf_counter()
            state, mets = train_step(state, batch)
            # ONE host pull per step: device_get materialises every
            # metric at once (blocking until the step finishes), so the
            # guard, the tracker, and the log_every print below all
            # read host floats — the old block_until_ready + repeated
            # float(...) shape synced the device once per metric read.
            mets = jax.device_get(mets)
            dt = time.perf_counter() - t0
            self._watchdog(i, dt)
            # Non-finite guard bookkeeping: "skipped" rides the metrics
            # pull the loop already blocks on — no extra syncs.
            if float(mets.get("skipped", 0.0)) > 0:
                skipped_steps += 1
                consecutive_skips += 1
                self.log_fn(
                    f"[trainer] step {i + 1} SKIPPED non-finite update "
                    f"(loss={float(mets['loss'])}, "
                    f"grad_norm={float(mets['grad_norm'])}; "
                    f"{consecutive_skips} consecutive)"
                )
                if (self.tc.max_consecutive_skips > 0
                        and consecutive_skips
                        >= self.tc.max_consecutive_skips):
                    raise RuntimeError(
                        f"training diverged: {consecutive_skips} "
                        "consecutive non-finite losses (last loss="
                        f"{float(mets['loss'])}, grad_norm="
                        f"{float(mets['grad_norm'])}) — lower the "
                        "learning rate, raise router z-loss, or resume "
                        "from the last checkpoint with a different "
                        "data seed"
                    )
            else:
                consecutive_skips = 0
            mets["skipped_steps"] = skipped_steps
            # Tracker: every step, not just every log_every.
            self.trk.row(
                "train", t=i + 1,
                loss=float(mets["loss"]), ce=float(mets["ce"]),
                grad_norm=float(mets["grad_norm"]),
                skipped=float(mets.get("skipped", 0.0)),
                skipped_steps=skipped_steps,
                step_ms=dt * 1e3,
            )
            if float(mets.get("skipped", 0.0)) > 0:
                self.trk.count("train.skipped_steps", t=i + 1)
            if (i + 1) % self.tc.log_every == 0:
                self.log_fn(
                    f"[trainer] step {i + 1} loss={float(mets['loss']):.4f} "
                    f"ce={float(mets['ce']):.4f} {dt * 1e3:.0f}ms"
                )
            want_ckpt = (i + 1) % self.tc.checkpoint_every == 0
            if want_ckpt or self.preemption:
                self.manager.save(
                    i + 1, state,
                    metadata={"data": self.data.state(),
                              "arch": self.cfg.name},
                    blocking=bool(self.preemption),
                )
            if self.preemption:
                self.log_fn(
                    f"[trainer] preempted at step {i + 1}; "
                    "checkpoint saved, exiting cleanly"
                )
                break
        self.manager.wait()
        return {"state": state, "metrics": mets}

    def _watchdog(self, step: int, dt: float) -> None:
        self._step_times.append(dt)
        if len(self._step_times) < 8:
            return
        med = float(np.median(self._step_times[-64:]))
        if dt > self.tc.straggler_factor * med:
            self.log_fn(
                f"[trainer][straggler] step {step} took {dt * 1e3:.0f}ms "
                f"(median {med * 1e3:.0f}ms) — on a pod this triggers the "
                "slow-host report"
            )

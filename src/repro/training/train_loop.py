"""Self-healing training loop: jitted train_step (grad accumulation,
compression, remat), divergence rollback, bit-exact crash-resume,
preemption handling.

``make_train_step`` builds a pure (state, batch[, lr_scale]) ->
(state, metrics) function; distribution comes entirely from in/out
shardings + the logical constraints inside the model (GSPMD) — the same
function serves 1 CPU device and a 512-chip mesh.

``Trainer`` is the fault-tolerant driver. Failure modes it survives
(the train-side mirror of the serve stack's table in
``repro/serve/__init__.py``; overview in ``repro/training/__init__``):

* **finite loss spike** (divergence) — the :class:`SpikeDetector`
  flags ``loss > spike_threshold × trailing median``; the Trainer
  restores the last known-good checkpoint, fast-forwards the data
  iterator past the offending batch window (PaLM-style batch skip),
  optionally decays the LR for a cooldown, and aborts with the full
  rollback history after ``max_rollbacks``;
* **NaN/inf loss** — the in-step non-finite guard drops the update
  (params/opt state/residual keep their old values) at zero extra host
  syncs; abort after ``max_consecutive_skips`` consecutive skips;
* **crash / kill** — every checkpoint carries ALL resume-relevant
  state (data-iterator position, skip counters, rollback history, LR
  cooldown, detector window) so kill-at-step-k + auto-resume is
  bit-identical to an uninterrupted run (tests/test_train_chaos.py);
* **preemption** — cooperative SIGTERM: final blocking save + clean
  exit; the restarted job resumes;
* **flaky / corrupt checkpoint store** — the CheckpointManager retries
  transient IO with capped backoff and ``restore_latest`` falls back
  past torn payloads to the last known-good step.

Fault injection for all of the above lives in
``repro.training.chaos`` (:class:`TrainChaosConfig` + ``run_chaotic``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataIterator
from repro.obs.tracker import NULL, Tracker
from repro.models import model_zoo as zoo
from repro.models import param as pm
from repro.optim.base import Optimizer, apply_updates, global_norm
from repro.sharding import ShardCtx, act
from repro.training import compression
from repro.training.chaos import ChaosState, SimulatedCrash, TrainChaosConfig
from repro.training.health import SpikeDetector


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    grad_accum: int = 1
    compression: str = "none"  # none | bf16 | int8
    checkpoint_every: int = 100
    log_every: int = 10
    max_to_keep: int = 3
    # straggler watchdog: warn when a step takes > factor * median
    straggler_factor: float = 3.0
    # Non-finite loss guard: a NaN/inf loss or grad norm skips the
    # optimizer update (params/opt state/residual keep their old
    # values, the step counter still advances — MoE router blowups are
    # the classic upcycling fine-tune failure); the Trainer aborts with
    # a clear error after this many CONSECUTIVE skips. 0 disables the
    # guard entirely (step applies whatever it computed).
    max_consecutive_skips: int = 10
    # Divergence (FINITE loss spike) detection + rollback. A loss >
    # spike_threshold × trailing baseline (median of the last
    # spike_window finite losses, armed after spike_min_history steps)
    # triggers restore-from-last-known-good + a batch-window skip.
    # 0.0 disables detection (default — short smoke runs with jumpy
    # early losses opt in explicitly).
    spike_threshold: float = 0.0
    spike_window: int = 32
    spike_min_history: int = 5
    spike_mode: str = "median"  # median | ewma
    # Rollback policy: skip the data stream to offending_batch +
    # rollback_skip (the PaLM-style window skip — the bad batch never
    # recurs), decay LR by rollback_lr_decay for rollback_cooldown
    # steps after the restore, and abort with the full rollback
    # history after max_rollbacks rollbacks.
    max_rollbacks: int = 3
    rollback_skip: int = 8
    rollback_lr_decay: float = 1.0
    rollback_cooldown: int = 0


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    ac: zoo.ApplyCfg = zoo.ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
    tc: TrainConfig = TrainConfig(),
):
    """Returns train_step(state, batch[, lr_scale]) -> (state, metrics).

    Kernel implementations come from ``ac`` (ApplyCfg): the default
    "auto" resolves here — at step-build time, so the jitted step traces
    with a concrete choice — to the fused Pallas forward+backward kernels
    on TPU and the XLA einsum path on CPU.

    ``lr_scale`` (optional traced scalar) multiplies the optimizer
    updates — the post-rollback LR-cooldown knob. The Trainer always
    passes it as a jnp scalar so the jitted step keeps ONE signature
    (no retrace when the scale changes); omitting it traces without the
    multiply, preserving the original two-arg call.
    """
    ac = ac.resolve()

    def grads_of(params, batch):
        (loss, mets), grads = jax.value_and_grad(
            zoo.loss_fn, has_aux=True
        )(params, batch, cfg, ac=ac, ctx=ctx)
        return grads, mets

    def train_step(state, batch, lr_scale=None):
        params = state["params"]
        if tc.grad_accum > 1:
            def micro(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb)
                return (
                    jax.tree.map(jnp.add, g_acc, g),
                    jax.tree.map(jnp.add, m_acc, m),
                ), None

            def reshape(x):
                b = x.shape[0]
                return x.reshape(
                    (tc.grad_accum, b // tc.grad_accum) + x.shape[1:]
                )

            micro_batches = jax.tree.map(reshape, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            from repro.models.stack import zero_metrics

            m0 = dict(zero_metrics())
            m0.update(loss=jnp.zeros(()), ce=jnp.zeros(()))
            (grads, mets), _ = jax.lax.scan(
                micro, (g0, m0), micro_batches
            )
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
            mets = jax.tree.map(lambda m: m / tc.grad_accum, mets)
        else:
            grads, mets = grads_of(params, batch)

        if tc.compression != "none":
            grads, residual = compression.compress(
                grads, state["residual"], tc.compression
            )
        else:
            residual = state.get("residual")

        updates, opt_state = optimizer.update(
            grads, state["opt_state"], params
        )
        if lr_scale is not None:
            updates = jax.tree.map(lambda u: u * lr_scale, updates)
        new_params = apply_updates(params, updates)
        mets = dict(mets)
        grad_norm = global_norm(grads)
        mets["grad_norm"] = grad_norm
        if tc.max_consecutive_skips > 0:
            # Non-finite guard: keep the OLD params/opt state/residual
            # when the loss or grad norm blew up — all inside the jitted
            # step (jnp.where), zero extra host syncs; the Trainer reads
            # mets["skipped"] off the metrics it already pulls.
            ok = jnp.isfinite(mets["loss"]) & jnp.isfinite(grad_norm)

            def pick(new, old):
                return jax.tree.map(
                    lambda a, b: jnp.where(ok, a, b), new, old
                )

            new_params = pick(new_params, params)
            opt_state = pick(opt_state, state["opt_state"])
            if residual is not None and "residual" in state:
                residual = pick(residual, state["residual"])
            mets["skipped"] = (~ok).astype(jnp.float32)
        else:
            mets["skipped"] = jnp.zeros((), jnp.float32)
        new_state = dict(state)
        new_state.update(
            params=new_params,
            opt_state=opt_state,
            # The step counter tracks consumed batches, so checkpoint /
            # resume bookkeeping is oblivious to skipped updates.
            step=state["step"] + 1,
        )
        if residual is not None:
            new_state["residual"] = residual
        return new_state, mets

    return train_step


def init_train_state(
    rng,
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    dtype=jnp.float32,
    tc: TrainConfig = TrainConfig(),
    params: Any = None,
):
    """params: optional pre-built plain-array tree (e.g. upcycled)."""
    if params is None:
        wrapped = zoo.init_params(rng, cfg, dtype=dtype)
        params, _ = pm.split(wrapped)
    state = {
        "params": params,
        "opt_state": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tc.compression != "none":
        state["residual"] = compression.init_residual(params)
    return state


def state_axes(cfg: ArchConfig, *, dtype=jnp.float32,
               tc: TrainConfig = TrainConfig()):
    """Logical-axes tree matching init_train_state's structure."""
    wrapped = jax.eval_shape(
        lambda: zoo.init_params(jax.random.PRNGKey(0), cfg, dtype=dtype)
    )
    vals, axes = pm.split(wrapped)
    opt_axes = {
        "step": "",
        "slots": _adafactor_slot_axes(axes, vals),
    }
    out = {"params": axes, "opt_state": opt_axes, "step": ""}
    if tc.compression != "none":
        out["residual"] = axes
    return out


def _adafactor_slot_axes(axes_tree, shapes_tree):
    """Map param logical axes -> adafactor slot axes ({v_row, v_col} or
    {v}); mirrors optim/adafactor._factored exactly."""
    from repro.optim.adafactor import _factored

    def one(a: str, shaped):
        names = a.split() if a else []
        if _factored(tuple(shaped.shape)):
            return {
                "v_row": " ".join(names[:-1]),
                "v_col": " ".join(names[:-2] + names[-1:]),
            }
        return {"v": a}

    return jax.tree.map(one, axes_tree, shapes_tree)


class PreemptionSignal:
    """Cooperative preemption flag (SIGTERM handler or test hook)."""

    def __init__(self):
        self._flag = False

    def install(self):
        import signal

        def handler(signum, frame):
            self._flag = True

        signal.signal(signal.SIGTERM, handler)
        return self

    def trigger(self):
        self._flag = True

    def __bool__(self):
        return self._flag


@dataclasses.dataclass
class Trainer:
    cfg: ArchConfig
    optimizer: Optimizer
    data: DataIterator
    ckpt_dir: str
    ac: zoo.ApplyCfg = zoo.ApplyCfg()
    ctx: Optional[ShardCtx] = None
    tc: TrainConfig = TrainConfig()
    preemption: Optional[PreemptionSignal] = None
    log_fn: Callable[[str], None] = print
    # Observability: one "train" row per step (loss / ce / grad_norm /
    # skipped_steps / spike / rollbacks / lr_scale / step_ms) plus
    # checkpoint retry/fallback counters — log_fn keeps the old
    # print-style behaviour alongside.
    tracker: Optional[Tracker] = None
    # Seeded fault injection (repro/training/chaos.py). chaos_state is
    # harness-owned so its ledger survives simulated process crashes;
    # a bare chaos config gets a private state.
    chaos: Optional[TrainChaosConfig] = None
    chaos_state: Optional[ChaosState] = None

    def __post_init__(self):
        self.trk = self.tracker if self.tracker is not None else NULL
        if self.chaos is not None and self.chaos_state is None:
            self.chaos_state = ChaosState(self.chaos)
        self.manager = CheckpointManager(
            self.ckpt_dir, max_to_keep=self.tc.max_to_keep,
            tracker=self.trk,
            fault_hook=(self.chaos_state.fault_hook
                        if self.chaos_state is not None else None),
        )
        self._step_times: list[float] = []
        self.detector = SpikeDetector(
            self.tc.spike_threshold, window=self.tc.spike_window,
            min_history=self.tc.spike_min_history,
            mode=self.tc.spike_mode,
        )
        self._skipped_steps = 0
        self._consecutive_skips = 0
        self._rollbacks: list[dict] = []
        self._cooldown_left = 0
        self.stats: dict = {}

    # -- resume-relevant trainer state ----------------------------------
    # Everything the loop needs beyond the param/opt tree rides in
    # checkpoint metadata, so kill-at-step-k + resume replays
    # bit-identically: data-iterator position (+ skip history), skip
    # counters, rollback history, LR cooldown, detector window.
    def _trainer_meta(self) -> dict:
        return {
            "skipped_steps": self._skipped_steps,
            "consecutive_skips": self._consecutive_skips,
            "rollbacks": list(self._rollbacks),
            "cooldown_left": self._cooldown_left,
            "detector": self.detector.state(),
        }

    def _restore_trainer_meta(self, meta: dict, *,
                              keep_rollbacks: bool = False) -> None:
        tm = meta.get("trainer", {})
        self._skipped_steps = int(tm.get("skipped_steps", 0))
        self._consecutive_skips = int(tm.get("consecutive_skips", 0))
        if not keep_rollbacks:
            self._rollbacks = list(tm.get("rollbacks", []))
        self._cooldown_left = int(tm.get("cooldown_left", 0))
        self.detector.restore(tm.get("detector", {}))

    def _save(self, step: int, state, *, blocking: bool) -> None:
        self.manager.save(
            step, state,
            metadata={"data": self.data.state(),
                      "arch": self.cfg.name,
                      "trainer": self._trainer_meta()},
            blocking=blocking,
        )

    # -- divergence rollback --------------------------------------------
    def _rollback(self, like, bad_step: int, bad_batch: int,
                  obs_loss: float):
        """Restore the last known-good checkpoint, rewind the trainer
        bookkeeping to that checkpoint's view, and fast-forward the
        data iterator past the offending batch window. Returns the
        restored state tree."""
        base = self.detector.baseline()
        self.manager.wait()  # an async save may still be writing
        restored, gstep, meta = self.manager.restore_latest(like)
        if restored is None:
            raise RuntimeError(
                f"training diverged at step {bad_step} "
                f"(loss={obs_loss:.6g}, baseline={base}) and no valid "
                "checkpoint exists to roll back to — every candidate "
                "was corrupt or missing"
            )
        # Rewind bookkeeping to the checkpoint's view — but the
        # rollback HISTORY is cumulative across the run (the
        # max_rollbacks bound must see every rollback, including ones
        # newer than the restored step).
        self.data.restore(meta.get("data", {"step": gstep}))
        self._restore_trainer_meta(meta, keep_rollbacks=True)
        # PaLM-style batch-window skip: the stream resumes PAST the
        # offending batch, so a deterministic bad batch cannot re-fire.
        skip_to = bad_batch + max(1, self.tc.rollback_skip)
        if skip_to > self.data.step:
            self.data.skip(skip_to - self.data.step)
        self._cooldown_left = max(0, self.tc.rollback_cooldown)
        rec = {
            "step": int(bad_step),
            "loss": float(obs_loss),
            "baseline": None if base is None else float(base),
            "restored_to": int(gstep),
            "batch": int(bad_batch),
            "data_skipped_to": int(self.data.step),
        }
        self._rollbacks.append(rec)
        self.trk.count("train.rollbacks", t=bad_step)
        self.trk.event("rollback", t=bad_step, **rec)
        self.log_fn(
            f"[trainer] step {bad_step} DIVERGED "
            f"(loss={obs_loss:.4g} > {self.tc.spike_threshold:g}× "
            f"baseline {0.0 if base is None else base:.4g}); rolled "
            f"back to step {gstep}, data skipped to batch "
            f"{self.data.step} ({len(self._rollbacks)}/"
            f"{self.tc.max_rollbacks} rollbacks)"
        )
        return restored, gstep

    def _abort_diverged(self, bad_step: int, obs_loss: float) -> None:
        base = self.detector.baseline()
        hist = "; ".join(
            f"step {r['step']}: loss {r['loss']:.4g} -> restored to "
            f"{r['restored_to']}, skipped to batch "
            f"{r['data_skipped_to']}" for r in self._rollbacks
        )
        raise RuntimeError(
            f"training diverged: loss spike at step {bad_step} "
            f"(loss={obs_loss:.6g} > {self.tc.spike_threshold:g}× "
            f"baseline {0.0 if base is None else base:.6g}) after "
            f"{len(self._rollbacks)} rollbacks "
            f"[{hist}] — lower the learning rate, widen "
            "rollback_skip past the bad data window, or raise router "
            "z-loss before resuming"
        )

    # -- chaos audit -----------------------------------------------------
    def audit(self, step: int) -> None:
        """Per-step invariant audit (chaos harness): bookkeeping the
        self-healing machinery relies on must hold after every step,
        rollback, resume, and fault."""
        assert len(self.detector.history) <= self.detector.window
        assert len(self._rollbacks) <= self.tc.max_rollbacks
        assert 0 <= self._cooldown_left <= max(
            0, self.tc.rollback_cooldown)
        assert self.data.step >= step, (
            f"data iterator at batch {self.data.step} is behind "
            f"optimizer step {step}"
        )
        steps = self.manager.all_steps()
        assert steps == sorted(set(steps))
        assert self._consecutive_skips <= self._skipped_steps \
            or self._skipped_steps == 0
        if self.chaos_state is not None:
            self.chaos_state.audits += 1

    def run(self, num_steps: int, *, rng=None, init_params=None) -> dict:
        rng = jax.random.PRNGKey(0) if rng is None else rng
        state = init_train_state(
            rng, self.cfg, self.optimizer, tc=self.tc, params=init_params
        )
        # ---- auto-resume -------------------------------------------------
        restored, step0, meta = self.manager.restore_latest(state)
        if restored is not None:
            state = restored
            self.data.restore(meta.get("data", {"step": step0}))
            self._restore_trainer_meta(meta)
            self.log_fn(f"[trainer] resumed from step {step0}")
        train_step = jax.jit(
            make_train_step(
                self.cfg, self.optimizer, ac=self.ac, ctx=self.ctx,
                tc=self.tc,
            ),
            donate_argnums=(0,),
        )
        self._train_step = train_step
        # Rollback anchor: divergence before the first periodic save
        # still needs a known-good restore target.
        if self.detector.enabled and self.manager.latest_step() is None:
            self._save(0, state, blocking=True)
        mets = {}
        step = int(state["step"])
        while step < num_steps:
            i = step
            batch = next(self.data)
            bidx = self.data.step - 1  # index of the batch just consumed
            lr_scale = (self.tc.rollback_lr_decay
                        if self._cooldown_left > 0 else 1.0)
            t0 = time.perf_counter()
            # lr_scale rides as a TRACED jnp scalar: one jit signature
            # for the whole run — cooldown decay never retraces.
            state, mets = train_step(state, batch,
                                     jnp.float32(lr_scale))
            # ONE host pull per step: device_get materialises every
            # metric at once (blocking until the step finishes), so the
            # guard, the tracker, and the log_every print below all
            # read host floats — the old block_until_ready + repeated
            # float(...) shape synced the device once per metric read.
            mets = jax.device_get(mets)
            dt = time.perf_counter() - t0
            self._watchdog(i, dt)
            obs_loss = float(mets["loss"])
            if self.chaos_state is not None \
                    and self.chaos_state.spike_at(bidx):
                obs_loss = obs_loss * self.chaos.spike_scale
            skipped = float(mets.get("skipped", 0.0)) > 0
            # Non-finite guard bookkeeping: "skipped" rides the metrics
            # pull the loop already blocks on — no extra syncs.
            if skipped:
                self._skipped_steps += 1
                self._consecutive_skips += 1
                self.log_fn(
                    f"[trainer] step {i + 1} SKIPPED non-finite update "
                    f"(loss={float(mets['loss'])}, "
                    f"grad_norm={float(mets['grad_norm'])}; "
                    f"{self._consecutive_skips} consecutive)"
                )
                if (self.tc.max_consecutive_skips > 0
                        and self._consecutive_skips
                        >= self.tc.max_consecutive_skips):
                    raise RuntimeError(
                        f"training diverged: {self._consecutive_skips} "
                        "consecutive non-finite losses (last loss="
                        f"{float(mets['loss'])}, grad_norm="
                        f"{float(mets['grad_norm'])}) — lower the "
                        "learning rate, raise router z-loss, or resume "
                        "from the last checkpoint with a different "
                        "data seed"
                    )
            else:
                self._consecutive_skips = 0
            mets["skipped_steps"] = self._skipped_steps
            spike = (not skipped) and self.detector.is_spike(obs_loss)
            # Tracker: every step, not just every log_every — spike
            # steps included (their row precedes the rollback).
            self.trk.row(
                "train", t=i + 1,
                loss=obs_loss, ce=float(mets["ce"]),
                grad_norm=float(mets["grad_norm"]),
                skipped=float(mets.get("skipped", 0.0)),
                skipped_steps=self._skipped_steps,
                spike=float(spike),
                rollbacks=len(self._rollbacks),
                lr_scale=lr_scale,
                step_ms=dt * 1e3,
            )
            if skipped:
                self.trk.count("train.skipped_steps", t=i + 1)
            if spike:
                # Divergence: restore last-known-good + batch-window
                # skip, or abort with the full history once the
                # rollback budget is spent.
                if len(self._rollbacks) >= self.tc.max_rollbacks:
                    self._abort_diverged(i + 1, obs_loss)
                state, step = self._rollback(state, i + 1, bidx,
                                             obs_loss)
                if self.chaos is not None and self.chaos.audit:
                    self.audit(step)
                continue
            self.detector.update(obs_loss)
            if self._cooldown_left > 0:
                self._cooldown_left -= 1
            step = i + 1
            if step % self.tc.log_every == 0:
                self.log_fn(
                    f"[trainer] step {step} loss={float(mets['loss']):.4f} "
                    f"ce={float(mets['ce']):.4f} {dt * 1e3:.0f}ms"
                )
            if self.chaos_state is not None and self.preemption is not None \
                    and self.chaos_state.preempt_at(step):
                self.preemption.trigger()
            # A chaos crash fires BEFORE this step's checkpoint — the
            # worst case: everything since the last save is lost and
            # must replay bit-identically on resume.
            if self.chaos_state is not None \
                    and self.chaos_state.crash_at(step):
                raise SimulatedCrash(f"chaos: crash after step {step}")
            want_ckpt = step % self.tc.checkpoint_every == 0
            if want_ckpt or self.preemption:
                self._save(step, state, blocking=bool(self.preemption))
                if self.chaos_state is not None:
                    self.chaos_state.maybe_corrupt(self.manager, step)
            if self.chaos is not None and self.chaos.audit:
                self.audit(step)
            if self.preemption:
                self.log_fn(
                    f"[trainer] preempted at step {step}; "
                    "checkpoint saved, exiting cleanly"
                )
                break
        self.manager.wait()
        self.stats = {
            "skipped_steps": self._skipped_steps,
            "rollbacks": list(self._rollbacks),
            "cooldown_left": self._cooldown_left,
            "resumed_from": step0,
            # Rollback restores state without retracing: ONE signature
            # for the whole run, rollbacks and LR cooldowns included.
            "compile_count": train_step._cache_size(),
            "store": self.manager.health(),
        }
        return {"state": state, "metrics": mets, "stats": self.stats}

    def _watchdog(self, step: int, dt: float) -> None:
        self._step_times.append(dt)
        if len(self._step_times) < 8:
            return
        med = float(np.median(self._step_times[-64:]))
        if dt > self.tc.straggler_factor * med:
            self.log_fn(
                f"[trainer][straggler] step {step} took {dt * 1e3:.0f}ms "
                f"(median {med * 1e3:.0f}ms) — on a pod this triggers the "
                "slow-host report"
            )

"""Routers: Expert Choice, Top-K (with BPR), Switch (Top-1).

Routing operates on token *groups* (paper §A.1.1: group size <= 4096): the
top-k / capacity bookkeeping is local to each group, which bounds the
routing working set and — on hardware — the all-to-all payloads.

All routers return a ``Routing`` carrying integer dispatch indices, combine
weights, and metrics. Three dispatch implementations live in core/moe.py:
the paper-era one-hot einsum (faithful baseline), gather/scatter
(optimized padded), and sorted ragged (grouped-GEMM, no capacity buffer).
Token-choice routers additionally expose the token-major assignment view
(``token_expert``/``token_weight``) the sorted path consumes.

Shapes: x grouped as (G, g, d); router logits (G, g, E); expert buffers
(G, E, cap, d).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import MoECfg
from repro.models import param as pm


class Routing(NamedTuple):
    # For every expert slot (G, E, cap): which group-local token fills it.
    # Token id == g (out of range) marks an unfilled slot.
    token_idx: jax.Array  # int32 (G, E, cap)
    # Combine weight for each expert slot (0 where unfilled) (G, E, cap).
    combine: jax.Array
    # Router probabilities (G, g, E) — kept for the einsum dispatch path
    # and for metrics.
    probs: jax.Array
    aux_loss: jax.Array  # scalar
    z_loss: jax.Array  # scalar
    # Fraction of tokens processed by no expert (dropped) — scalar metric.
    dropped_frac: jax.Array
    # Token-major assignments for the sorted ragged dispatch (token-choice
    # routers only; None for Expert Choice, whose slot table is already
    # expert-major and fully dense). (G, g, k) int32 expert id per
    # assignment — id == E marks a capacity-dropped assignment — and the
    # matching combine weight (0 where dropped). Mirrors the slot table
    # exactly: same capacity claims, same drops, same weights.
    token_expert: Optional[jax.Array] = None  # int32 (G, g, k)
    token_weight: Optional[jax.Array] = None  # f32 (G, g, k)


def router_init(rng, d_model: int, moe: MoECfg):
    return {
        "w": pm.normal(
            rng, (d_model, moe.num_experts), "embed expert",
            std=moe.router_init_std,
        )
    }


def _z_loss(logits) -> jax.Array:
    return jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))


def capacity(group: int, moe: MoECfg) -> int:
    """Tokens per expert per group (paper §2.1: cap = C * n / E)."""
    cap = max(1, -(-int(group * moe.capacity_factor) // moe.num_experts))
    return min(cap, group)


def route_expert_choice(logits: jax.Array, moe: MoECfg) -> Routing:
    """Expert Choice (Zhou et al. 2022): every expert picks its top-cap
    tokens (top-k per column). Always perfectly load balanced."""
    G, g, E = logits.shape
    cap = capacity(g, moe)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    # (G, E, g): experts choose tokens.
    weights, token_idx = jax.lax.top_k(probs.transpose(0, 2, 1), cap)
    combine = weights  # (G, E, cap)

    if moe.normalize_combine_weights:
        combine = _normalize_per_token(token_idx, combine, g)

    # Dropped tokens: selected by no expert.
    sel = jnp.zeros((G, g + 1), jnp.float32)
    sel = _scatter_add_groups(sel, token_idx, jnp.ones_like(combine))
    dropped = jnp.mean((sel[:, :g] == 0).astype(jnp.float32))

    # EC is perfectly load balanced by construction: no aux loss (the
    # weighted zero keeps the metrics tree shape identical to Top-K).
    aux = jnp.zeros((), jnp.float32)
    return Routing(
        token_idx=token_idx,
        combine=combine,
        probs=probs,
        aux_loss=aux,
        z_loss=_z_loss(logits) if moe.z_loss_weight else jnp.zeros(()),
        dropped_frac=dropped,
    )


def route_top_k(
    logits: jax.Array,
    moe: MoECfg,
    *,
    k: Optional[int] = None,
    bpr: Optional[bool] = None,
    token_mask: Optional[jax.Array] = None,
) -> Routing:
    """Top-K token-choice routing (Shazeer et al. 2017 / GShard) with
    capacity buffers, optional Batch Prioritized Routing (paper §B.1).

    ``token_mask`` (G, g) bool: False marks dead tokens (continuous-
    batching decode slots that hold no request) — their assignments are
    forced to the trash expert id E *before* capacity accounting, so
    they claim no capacity, appear in no dispatch table, and carry zero
    combine weight. Live tokens' routing is unchanged."""
    G, g, E = logits.shape
    k = moe.top_k if k is None else k
    bpr = moe.bpr if bpr is None else bpr
    cap = capacity(g, moe)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (G, g, K)
    if token_mask is not None:
        top_e = jnp.where(token_mask[..., None], top_e, E)

    def positions_of(top_e_local):
        """Capacity claims in token-major, k-minor order."""
        oh = jax.nn.one_hot(top_e_local, E, dtype=jnp.int32)  # (G,g,K,E)
        flat = oh.reshape(G, g * k, E)
        pos_flat = jnp.cumsum(flat, axis=1) - flat  # claims before this
        return (pos_flat * flat).sum(-1).reshape(G, g, k)

    # Priority order for capacity claims: BPR gives capacity to the most
    # confident tokens first; default is natural (causal-safe) order.
    # Implemented with lax.sort round trips (NOT batched gathers — those
    # hit an XLA-client version skew in this environment under scan).
    if bpr:
        # Integer bookkeeping only — no gradients flow through priority
        # order, and lax.sort's JVP would itself emit batched gathers.
        neg_conf = jax.lax.stop_gradient(-top_w[..., 0])  # (G, g)
        token_ids = jnp.broadcast_to(
            jnp.arange(g, dtype=jnp.int32), (G, g)
        )
        sorted_ops = jax.lax.sort(
            (neg_conf, token_ids)
            + tuple(top_e[..., i] for i in range(k)),
            dimension=1, num_keys=1,
        )
        orig_idx = sorted_ops[1]
        top_e_sorted = jnp.stack(sorted_ops[2:], axis=-1)
        pos_s = positions_of(top_e_sorted)
        keep_s = (pos_s < cap).astype(jnp.int32)
        # un-sort back to natural token order
        unsorted = jax.lax.sort(
            (orig_idx,)
            + tuple(pos_s[..., i] for i in range(k))
            + tuple(keep_s[..., i] for i in range(k)),
            dimension=1, num_keys=1,
        )
        pos = jnp.stack(unsorted[1:1 + k], axis=-1)
        keep = jnp.stack(unsorted[1 + k:], axis=-1).astype(bool)
    else:
        pos = positions_of(top_e)
        keep = pos < cap

    if token_mask is not None:
        keep = keep & token_mask[..., None]
    w = top_w * keep
    if moe.normalize_combine_weights:
        denom = jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        w = w / denom

    # Scatter (token, k) claims into expert slot table (G, E, cap).
    token_ids = jnp.broadcast_to(jnp.arange(g)[None, :, None], (G, g, k))
    slot_e = jnp.where(keep, top_e, E)  # overflow -> expert E (trash row)
    slot_p = jnp.where(keep, pos, cap)
    token_idx = jnp.full((G, E + 1, cap + 1), g, jnp.int32)
    combine = jnp.zeros((G, E + 1, cap + 1), jnp.float32)
    gi = jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, g, k))
    token_idx = token_idx.at[gi, slot_e, slot_p].set(token_ids)
    combine = combine.at[gi, slot_e, slot_p].set(w)
    token_idx = token_idx[:, :E, :cap]
    combine = combine[:, :E, :cap]

    # Metrics normalize over LIVE tokens when a mask is present, so a
    # mostly-free decode batch doesn't read as "75% dropped" and dead
    # tokens' router probs don't dilute the load-balance terms.
    no_keep = 1.0 - jnp.any(keep, axis=-1).astype(jnp.float32)
    # Load-balance aux loss (Switch/GShard form on top-1 assignments);
    # dead tokens' top_e is E, so their one-hot rows are already zero.
    top1 = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    if token_mask is None:
        dropped = jnp.mean(no_keep)
        density = top1.mean(axis=1)  # (G, E) fraction of tokens -> e
        p_mean = probs.mean(axis=1)  # (G, E)
    else:
        live = token_mask.astype(jnp.float32)  # (G, g)
        n_live = jnp.maximum(live.sum(-1, keepdims=True), 1.0)  # (G, 1)
        dropped = jnp.mean((no_keep * live).sum(-1) / n_live[:, 0])
        density = top1.sum(axis=1) / n_live
        p_mean = (probs * live[..., None]).sum(axis=1) / n_live
    aux = E * jnp.mean(jnp.sum(density * p_mean, axis=-1))

    return Routing(
        token_idx=token_idx,
        combine=combine,
        probs=probs,
        aux_loss=aux,
        z_loss=_z_loss(logits) if moe.z_loss_weight else jnp.zeros(()),
        dropped_frac=dropped,
        token_expert=jnp.where(keep, top_e, E).astype(jnp.int32),
        token_weight=w,
    )


def route(logits: jax.Array, moe: MoECfg, router_kind: str, *,
          token_mask: Optional[jax.Array] = None) -> Routing:
    if router_kind == "expert_choice":
        if token_mask is not None:
            # EC's per-expert top-cap would need column-wise masking;
            # decoders (the only place dead decode slots exist) always
            # route token-choice (stack_router_kind, paper §3.1).
            raise ValueError(
                "token_mask is only supported by token-choice routers"
            )
        return route_expert_choice(logits, moe)
    if router_kind == "top_k":
        return route_top_k(logits, moe, token_mask=token_mask)
    if router_kind == "switch":
        return route_top_k(logits, moe, k=1, token_mask=token_mask)
    raise ValueError(f"unknown router {router_kind!r}")


def assignment_stream(r: Routing, num_experts: int, group: int):
    """Flat per-group assignment stream ``(tok, eid, w)``, each ``(G, N)``:
    group-local token id, expert id and combine weight for every routing
    assignment. This is the common input of the sorted dispatches
    (single-device ragged sort in core/moe.py and the expert-parallel
    all-to-all in core/ep.py).

    Token-choice routers expose it token-major (their ``token_expert`` /
    ``token_weight`` views, N = g*k); Expert Choice slots are already
    expert-major and fully dense, so its slot table flattens directly
    (N = E*cap). Dropped/invalid assignments carry ``eid == E`` or
    ``tok == group``.
    """
    G = r.probs.shape[0]
    E = num_experts
    if r.token_expert is not None:
        A = r.token_expert.shape[-1]
        tok = jnp.broadcast_to(
            jnp.arange(group, dtype=jnp.int32)[None, :, None],
            (G, group, A),
        ).reshape(G, group * A)
        eid = r.token_expert.reshape(G, group * A)
        w = r.token_weight.reshape(G, group * A)
    else:
        cap = r.token_idx.shape[-1]
        eid = jnp.broadcast_to(
            jnp.arange(E, dtype=jnp.int32)[:, None], (E, cap)
        ).reshape(1, E * cap)
        eid = jnp.broadcast_to(eid, (G, E * cap))
        tok = r.token_idx.reshape(G, E * cap)
        w = r.combine.reshape(G, E * cap)
    return tok, eid, w


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _scatter_add_groups(tbl, idx, val):
    """tbl (G, g+1); idx (G, E, cap) group-local token ids; val same shape."""
    G = tbl.shape[0]
    gi = jnp.broadcast_to(
        jnp.arange(G)[:, None, None], idx.shape
    )
    return tbl.at[gi, idx].add(val)


def _normalize_per_token(token_idx, combine, g):
    """Paper §B.7: renormalize each token's combine weights to sum to 1.

    Tokens selected by no expert keep weight 0 (their output is 0 — i.e.
    residual passthrough in the transformer block).
    """
    G = combine.shape[0]
    denom = jnp.zeros((G, g + 1), jnp.float32)
    denom = _scatter_add_groups(denom, token_idx, combine)
    denom = jnp.maximum(denom, 1e-9)
    gi = jnp.broadcast_to(jnp.arange(G)[:, None, None], token_idx.shape)
    return combine / denom[gi, token_idx]

"""The sparse-upcycling surgery (paper §3, Figure 1).

``upcycle_params`` maps a trained dense checkpoint onto the sparse target
architecture: every parameter is copied verbatim except the MLPs of layers
that become MoE, which are *replicated into each expert*; routers are new,
randomly initialized (normal, std 0.02, §A.1.1).

``upcycle_opt_state`` optionally carries the dense optimizer slots across
(vision recipe, §B.6): slot arrays for tiled MLP weights are broadcast over
the new expert dim; router slots stay fresh (footnote 6).

``depth_tile`` implements the paper's *dense upcycling* baseline (Fig. 5,
following Gopher): warm-start a deeper dense model by replicating blocks.

All functions operate on *wrapped* trees (repro.models.param.Param) so
logical sharding axes are transformed alongside values.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, MoECfg
from repro.core.routing import router_init
from repro.models import param as pm
from repro.models import stack as stk


def _is_param(x):
    return isinstance(x, pm.Param)


def _tile_expert(prm: pm.Param, num_experts: int, *, rng=None,
                 noise_std: float = 0.0) -> pm.Param:
    v = jnp.broadcast_to(prm.value, (num_experts,) + prm.value.shape)
    if noise_std and rng is not None:
        v = v + noise_std * jax.random.normal(rng, v.shape, v.dtype)
    return pm.Param(v, ("expert " + prm.axes).strip())


def _expand_ffn(
    dense_ffn,
    cfg: ArchConfig,
    moe: MoECfg,
    rng,
):
    """Dense MLP params {wi[,wg],wo} -> MoE params {router, experts}."""
    kr, kn, ke = jax.random.split(rng, 3)
    if moe.expert_init == "random":
        # Ablation §B.5: experts from scratch.
        from repro.core.moe import moe_init

        fresh = moe_init(ke, cfg, moe)
        experts = fresh["experts"]
    else:
        noise = moe.init_noise_std if moe.expert_init == "copy_noise" else 0.0
        experts = {
            k: _tile_expert(
                v, moe.num_experts,
                rng=jax.random.fold_in(kn, i), noise_std=noise,
            )
            for i, (k, v) in enumerate(sorted(dense_ffn.items()))
        }
    return {"router": router_init(kr, cfg.d_model, moe), "experts": experts}


def _map_stack(
    dense_stack,
    dense_descs,
    target_descs,
    cfg: ArchConfig,
    moe: MoECfg,
    rng,
):
    if len(dense_descs) != len(target_descs):
        raise ValueError(
            f"layer count mismatch: dense {len(dense_descs)} vs "
            f"target {len(target_descs)}"
        )
    layers = stk.unstack_layers(dense_stack, dense_descs)
    out = []
    for l, (dl, dd, td) in enumerate(zip(layers, dense_descs, target_descs)):
        if dd.mixer != td.mixer or dd.cross != td.cross:
            raise ValueError(f"layer {l}: incompatible descs {dd} vs {td}")
        new = dict(dl)
        if td.ffn == "moe" and dd.ffn == "dense":
            new["ffn"] = _expand_ffn(
                dl["ffn"], cfg, moe, jax.random.fold_in(rng, l)
            )
        elif td.ffn != dd.ffn:
            raise ValueError(f"layer {l}: cannot map {dd.ffn} -> {td.ffn}")
        out.append(new)
    return stk.restack_layers(out, target_descs)


def upcycle_params(
    dense_params,
    dense_cfg: ArchConfig,
    target_cfg: ArchConfig,
    rng,
):
    """Dense wrapped param tree -> sparse wrapped param tree (Figure 1)."""
    moe = target_cfg.moe
    if moe is None:
        raise ValueError("target config has no MoE section")
    out = dict(dense_params)
    out["stack"] = _map_stack(
        dense_params["stack"],
        stk.layer_descs(dense_cfg, stack="decoder"),
        stk.layer_descs(target_cfg, stack="decoder"),
        target_cfg, moe, jax.random.fold_in(rng, 0),
    )
    if target_cfg.structure == "encoder_decoder":
        out["encoder"] = _map_stack(
            dense_params["encoder"],
            stk.layer_descs(dense_cfg, stack="encoder"),
            stk.layer_descs(target_cfg, stack="encoder"),
            target_cfg, moe, jax.random.fold_in(rng, 1),
        )
    return out


def _unstack_values(stack_tree, descs):
    """Like stack.unstack_layers but for plain value trees (slot dicts)."""
    segs = stk.find_segments(descs)
    layers = []
    for si, (reps, pdescs) in enumerate(segs):
        seg = stack_tree["segments"][si]
        for r in range(reps):
            for i in range(len(pdescs)):
                layers.append(
                    jax.tree.map(lambda v, r=r: v[r], seg[f"pos{i}"])
                )
    return layers


def _restack_values(layers, descs):
    segs = stk.find_segments(descs)
    out = []
    it = iter(layers)
    for reps, pdescs in segs:
        per_pos = {f"pos{i}": [] for i in range(len(pdescs))}
        for _ in range(reps):
            for i in range(len(pdescs)):
                per_pos[f"pos{i}"].append(next(it))
        out.append(
            {
                k: jax.tree.map(lambda *vs: jnp.stack(vs), *v)
                for k, v in per_pos.items()
            }
        )
    return {"segments": out}


def upcycle_opt_state(
    sparse_fresh_state,
    dense_state,
    dense_cfg: ArchConfig,
    target_cfg: ArchConfig,
):
    """Carry dense optimizer slots into the upcycled model (§B.6).

    ``sparse_fresh_state``: optimizer.init(upcycled_params) — provides the
    target structure; router slots keep their fresh values (paper
    footnote 6: the router has no dense counterpart). Slot arrays of MLPs
    that became experts are broadcast over the new leading expert dim —
    Adafactor factors over the LAST two dims, so a dense (d,) v_row tiles
    to (E, d) exactly (this is why optimizer-state upcycling is a pure
    broadcast with our factoring convention).
    """
    out = dict(sparse_fresh_state)
    out["slots"] = dict(sparse_fresh_state["slots"])
    dense_slots = dense_state["slots"]

    # Non-stack subtrees: copy verbatim (structures match).
    for key in dense_slots:
        if key in ("stack", "encoder"):
            continue
        out["slots"][key] = dense_slots[key]

    def map_stack(stack_key: str, which: str):
        ddescs = stk.layer_descs(dense_cfg, stack=which)
        tdescs = stk.layer_descs(target_cfg, stack=which)
        dlayers = _unstack_values(dense_slots[stack_key], ddescs)
        flayers = _unstack_values(
            sparse_fresh_state["slots"][stack_key], tdescs
        )
        merged = []
        for dl, fl, dd, td in zip(dlayers, flayers, ddescs, tdescs):
            new = dict(dl)
            if td.ffn == "moe" and dd.ffn == "dense":
                E = target_cfg.moe.num_experts
                experts = jax.tree.map(
                    lambda v: jnp.broadcast_to(v, (E,) + v.shape),
                    dl["ffn"],
                )
                new["ffn"] = {
                    "router": fl["ffn"]["router"],  # fresh
                    "experts": experts,
                }
            merged.append(new)
        return _restack_values(merged, tdescs)

    out["slots"]["stack"] = map_stack("stack", "decoder")
    if "encoder" in dense_slots:
        out["slots"]["encoder"] = map_stack("encoder", "encoder")
    # keep the dense step counter: the paper continues the LR schedule
    # where the dense checkpoint left off (§4.1).
    out["step"] = dense_state["step"]
    return out


def depth_tile(dense_params, dense_cfg: ArchConfig, factor: int):
    """Dense upcycling / depth tiling baseline (Fig. 5; Rae et al. 2021).

    Returns (tiled wrapped params, deeper ArchConfig). Tiling pattern:
    whole-network replication [L1..Ln, L1..Ln, ...].
    """
    descs = stk.layer_descs(dense_cfg, stack="decoder")
    layers = stk.unstack_layers(dense_params["stack"], descs)
    target_cfg = dataclasses.replace(
        dense_cfg,
        n_layers=dense_cfg.n_layers * factor,
        name=f"{dense_cfg.name}-depth{factor}x",
    )
    tdescs = stk.layer_descs(target_cfg, stack="decoder")
    out = dict(dense_params)
    out["stack"] = stk.restack_layers(layers * factor, tdescs)
    return out, target_cfg

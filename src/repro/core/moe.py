"""The MoE layer: router + dispatch + expert FFN + combine.

Two dispatch implementations:

* ``einsum`` — paper-era GShard-style one-hot matmul dispatch/combine
  (the *faithful baseline*; O(g * E * cap * d) extra FLOPs).
* ``gather`` — index gather/scatter dispatch (optimized; O(E * cap * d)).

Expert FFN compute goes through ``repro.kernels.ops.expert_ffn`` which
selects XLA einsums (default; used for CPU tests and dry-run lowering) or
the fused Pallas TPU kernel.

Sharding: dispatched buffers (G, E, cap, d) are constrained to
``_ expert cap embed`` — with experts on the ``model`` mesh axis this makes
GSPMD insert the all-to-alls of the paper's "expert partitioning"
(§A.4). When E doesn't divide the axis (grok), the constraint degrades to
replicated-expert + tensor-parallel d_ff via the rules engine.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, MoECfg
from repro.core import routing as R
from repro.models import param as pm
from repro.models.layers import activation
from repro.sharding import ShardCtx, act


def moe_init(rng, cfg: ArchConfig, moe: MoECfg, *, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.d_ff, moe.num_experts
    ks = jax.random.split(rng, 4)
    experts = {
        "wi": pm.dense(ks[0], (E, d, f), "expert embed mlp", dtype=dtype),
        "wo": pm.dense(
            ks[2], (E, f, d), "expert mlp embed", dtype=dtype, fan_in=f
        ),
    }
    if cfg.gated_mlp:
        experts["wg"] = pm.dense(
            ks[1], (E, d, f), "expert embed mlp", dtype=dtype
        )
    return {
        "router": R.router_init(ks[3], d, moe),
        "experts": experts,
    }


def expert_ffn(experts, xe, cfg: ArchConfig, *, implementation="xla",
               ctx: Optional[ShardCtx] = None):
    """xe: (G, E, cap, d) -> (G, E, cap, d). Dispatches to kernels.ops.

    Weights are constrained to their COMPUTE layout first: expert-resident
    ("expert _ _", one FSDP-style gather per layer) when E divides the
    `model` axis, else d_ff tensor-parallel. Without this GSPMD sometimes
    prefers replicating the token buffers over gathering the weights —
    ~4x more bytes at Jamba scale (EXPERIMENTS.md SPerf, jamba iteration 3).
    """
    from repro.kernels import ops

    wi, wg, wo = experts["wi"], experts.get("wg"), experts["wo"]
    if ctx is not None:
        E = wi.shape[0]
        model = dict(ctx.mesh.shape).get("model", 1)
        if E % model == 0:
            wi = act(ctx, wi, "expert _ _")
            wo = act(ctx, wo, "expert _ _")
            wg = act(ctx, wg, "expert _ _") if wg is not None else None
        else:
            wi = act(ctx, wi, "_ _ mlp")
            wo = act(ctx, wo, "_ mlp _")
            wg = act(ctx, wg, "_ _ mlp") if wg is not None else None
    return ops.expert_ffn(
        xe, wi, wg, wo,
        act=cfg.act,
        implementation=implementation,
    )


def _group(x2d: jax.Array, group_size: int):
    n, d = x2d.shape
    g = min(group_size, n)
    pad = (-n) % g
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return x2d.reshape(-1, g, d), n, pad


def moe_apply(
    params,
    x: jax.Array,
    cfg: ArchConfig,
    moe: MoECfg,
    *,
    router_kind: Optional[str] = None,
    dispatch: str = "gather",
    ctx: Optional[ShardCtx] = None,
    implementation: str = "xla",
):
    """x: (B, S, d) or (N, d). Returns (y, metrics dict)."""
    router_kind = router_kind or moe.router
    orig_shape = x.shape
    x2d = x.reshape(-1, x.shape[-1])
    xg, n, pad = _group(x2d, moe.group_size)
    G, g, d = xg.shape

    logits = jnp.einsum(
        "Ggd,de->Gge", xg, params["router"]["w"],
        preferred_element_type=jnp.float32,
    )
    r = R.route(logits, moe, router_kind)
    cap = r.token_idx.shape[-1]

    if dispatch == "einsum":
        # One-hot dispatch/combine (GShard-era faithful path).
        oh = jax.nn.one_hot(r.token_idx, g + 1, dtype=xg.dtype)[..., :g]
        # (G, E, cap, g) x (G, g, d) -> (G, E, cap, d)
        xe = jnp.einsum("Gect,Gtd->Gecd", oh, xg)
        xe = act(ctx, xe, "batch expert cap embed")
        ye = expert_ffn(params["experts"], xe, cfg,
                        implementation=implementation, ctx=ctx)
        ye = act(ctx, ye, "batch expert cap embed")
        comb = oh * r.combine[..., None].astype(xg.dtype)
        y = jnp.einsum("Gect,Gecd->Gtd", comb, ye)
    elif dispatch == "gather":
        safe_idx = jnp.minimum(r.token_idx, g - 1)
        gi = jnp.broadcast_to(
            jnp.arange(G)[:, None, None], r.token_idx.shape
        )
        xe = xg[gi, safe_idx]  # (G, E, cap, d)
        valid = (r.token_idx < g)[..., None].astype(xg.dtype)
        xe = xe * valid
        xe = act(ctx, xe, "batch expert cap embed")
        ye = expert_ffn(params["experts"], xe, cfg,
                        implementation=implementation, ctx=ctx)
        # Combine. Resharding ye from expert-sharded to hidden-sharded
        # BEFORE the scatter makes GSPMD emit a (tokens*k*d/E)-sized
        # all-to-all and a shard-local scatter, instead of partial-summing
        # the full (G, g, d) token buffer with an all-reduce per layer
        # (~E/k * 2 more bytes; see EXPERIMENTS.md SPerf jamba iteration).
        ye = act(ctx, ye, "batch _ cap mlp")
        w = (r.combine[..., None] * valid).astype(ye.dtype)
        yw = (ye * w).astype(xg.dtype)
        y = jnp.zeros((G, g + 1, d), xg.dtype)
        y = act(ctx, y, "batch seq mlp")
        y = y.at[gi, r.token_idx].add(yw)
        y = act(ctx, y, "batch seq mlp")
        y = y[:, :g]
    else:
        raise ValueError(f"unknown dispatch {dispatch!r}")

    y = y.reshape(-1, d)
    if pad:
        y = y[:n]
    y = y.reshape(orig_shape).astype(x.dtype)
    # Remat boundary tag: with stack_apply(remat="moe") only this combined
    # output is saved for the backward; the dispatched (G, E, cap, d)
    # buffers and router tensors above are recomputed.
    from jax.ad_checkpoint import checkpoint_name

    y = checkpoint_name(y, "moe_block")

    metrics = {
        "aux_loss": r.aux_loss * moe.aux_loss_weight,
        "z_loss": r.z_loss * moe.z_loss_weight,
        "dropped_frac": r.dropped_frac,
        "router_prob_mean_max": r.probs.max(-1).mean(),
    }
    return y, metrics

"""The MoE layer: router + dispatch + expert FFN + combine.

Three dispatch implementations (N = tokens/group g, A = assignments per
token — top-k's k; rows below are per group, f = d_ff):

  ========  ==================  =======================  =================
  dispatch  FFN rows processed  extra FLOPs vs dense     when to use
  ========  ==================  =======================  =================
  einsum    E*cap = C*g         one-hot dispatch AND     paper-faithful
            (scales with C)     combine matmuls:         baseline, tiny
                                O(g*E*cap*d) each        shapes, audits
  gather    E*cap = C*g         none (gather/scatter     padded default:
            (scales with C)     indexing only), but      expert-parallel
                                zero-pad FFN FLOPs on    a2a sharding via
                                unfilled slots           (G,E,cap,d) buf
  sorted    g*A + O(E*bm)       none; FFN FLOPs track    perf path: C > 1
            (independent of     *filled* rows only       or imbalanced
            capacity factor C)  (ragged grouped GEMM)    Top-K; finetune/
                                                         inference economy
  ========  ==================  =======================  =================

``einsum``/``gather`` build the padded ``(G, E, cap, d)`` capacity buffer
and go through ``kernels.ops.expert_ffn``; ``sorted`` sorts the flat
assignment stream by expert into a block-aligned ragged buffer
``(G, M, d)`` (M independent of capacity factor) and goes through
``kernels.ops.grouped_mlp`` — the scalar-prefetch Pallas grouped-GEMM
kernel on TPU, per-group ``lax.ragged_dot`` on XLA. All three consume the
same ``Routing`` decisions, so outputs/gradients agree to float tolerance
(tests/test_moe.py parity sweeps).

Sharding: the padded paths constrain dispatched buffers (G, E, cap, d) to
``_ expert cap embed`` — with experts on the ``model`` mesh axis this makes
GSPMD insert the all-to-alls of the paper's "expert partitioning"
(§A.4). When E doesn't divide the axis (grok), the constraint degrades to
replicated-expert + tensor-parallel d_ff via the rules engine. The sorted
path has two layouts, selected by ``moe.ep``:

  ==========  ===========  ================  ==========================
  layout      who moves    drops happen      sharding constraints
  ==========  ===========  ================  ==========================
  ep="none"   weights      router capacity   ragged buffer batch-
  (FSDP       (E weight    only (keep        sharded (``batch seq
  weight-     gathers to   masks, shared     embed``; dynamic expert
  gather)     the data     by all paths)     boundaries forbid an
              shards)                        expert axis); weights
                                             expert-resident when E
                                             divides ``model``, else
                                             d_ff tensor-parallel
  ep="a2a"    tokens (2    capacity PLUS     shard_map: token groups
  (expert-    ragged a2a   send-buffer       over every mesh axis,
  parallel,   exchanges    overflow past     weights over ``model``
  core/ep.py) over the     the static per-   (E/ep local experts per
              ``model``    peer row budget,  device); send/recv a2a
              axis)        ``ep_overflow_    buffers block-aligned,
                           frac`` metric     static (ep, budget, d)
  ==========  ===========  ================  ==========================

``ep="none"`` is the "Llama 3 Meets MoE" upcycling layout — weight
traffic scales with E; ``ep="a2a"`` trades it for token traffic that
scales with tokens/device (the GShard regime, where the capacity buffer
used to live) — see benchmarks/roofline.py ``comm.moe`` for the
crossover. Falls back to ``ep="none"`` when the mesh cannot host EP
(no ``model`` axis, size 1, or E % ep != 0 — the rules-engine
fallback discipline).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, MoECfg
from repro.core import routing as R
from repro.models import param as pm
from repro.models.layers import activation
from repro.sharding import ShardCtx, act


def moe_init(rng, cfg: ArchConfig, moe: MoECfg, *, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.d_ff, moe.num_experts
    ks = jax.random.split(rng, 4)
    experts = {
        "wi": pm.dense(ks[0], (E, d, f), "expert embed mlp", dtype=dtype),
        "wo": pm.dense(
            ks[2], (E, f, d), "expert mlp embed", dtype=dtype, fan_in=f
        ),
    }
    if cfg.gated_mlp:
        experts["wg"] = pm.dense(
            ks[1], (E, d, f), "expert embed mlp", dtype=dtype
        )
    return {
        "router": R.router_init(ks[3], d, moe),
        "experts": experts,
    }


def _compute_layout_weights(experts, ctx: Optional[ShardCtx]):
    """Constrain expert weights to their COMPUTE layout: expert-resident
    ("expert _ _", one FSDP-style gather per layer) when E divides the
    `model` axis, else d_ff tensor-parallel. Without this GSPMD sometimes
    prefers replicating the token buffers over gathering the weights —
    ~4x more bytes at Jamba scale (EXPERIMENTS.md SPerf, jamba iteration 3).
    Shared by the padded (expert_ffn) and sorted (grouped_mlp) paths."""
    wi, wg, wo = experts["wi"], experts.get("wg"), experts["wo"]
    if ctx is not None:
        E = wi.shape[0]
        model = dict(ctx.mesh.shape).get("model", 1)
        if E % model == 0:
            wi = act(ctx, wi, "expert _ _")
            wo = act(ctx, wo, "expert _ _")
            wg = act(ctx, wg, "expert _ _") if wg is not None else None
        else:
            wi = act(ctx, wi, "_ _ mlp")
            wo = act(ctx, wo, "_ mlp _")
            wg = act(ctx, wg, "_ _ mlp") if wg is not None else None
    return wi, wg, wo


def expert_ffn(experts, xe, cfg: ArchConfig, *, implementation="xla",
               ctx: Optional[ShardCtx] = None):
    """xe: (G, E, cap, d) -> (G, E, cap, d). Dispatches to kernels.ops."""
    from repro.kernels import ops

    wi, wg, wo = _compute_layout_weights(experts, ctx)
    return ops.expert_ffn(
        xe, wi, wg, wo,
        act=cfg.act,
        implementation=implementation,
    )


def _sorted_dispatch(params, xg, r, cfg: ArchConfig, moe: MoECfg, *,
                     ctx: Optional[ShardCtx], implementation: str,
                     block: int):
    """Sorted ragged dispatch: argsort the flat assignment stream by
    expert, run the contiguous ragged buffer through the grouped-GEMM
    kernel, unsort via scatter-add combine. Returns y (G, g, d).

    The ragged buffer has ``M = (ceil(N/block) + E) * block`` rows — N is
    the assignment count (g*k for token-choice), so FFN work is
    independent of capacity factor; capacity only decides WHICH
    assignments survive (the routers' keep masks, identical across
    dispatch paths).
    """
    from repro.kernels import ops
    from repro.kernels.grouped_mlp import ragged_destinations

    G, g, d = xg.shape
    E = moe.num_experts

    # Flat per-group assignment stream (token id, expert id, weight) —
    # shared with the expert-parallel path (core/ep.py).
    tok, eid, w = R.assignment_stream(r, E, g)
    N = tok.shape[1]
    valid = (eid < E) & (tok < g)
    key = jnp.where(valid, eid, E).astype(jnp.int32)

    # Stable sort by expert (dropped assignments -> key E, past the last
    # segment) and block-aligned ragged destinations — the layout math
    # shared with core/ep.py via kernels/grouped_mlp.py. Only the
    # integer permutation goes through lax.sort; the differentiable
    # weights follow via take_along_axis, so no gradient flows through
    # the sort itself.
    perm, key_s, counts, dest, M = ragged_destinations(key, E, block)
    tok_s = jnp.take_along_axis(tok, perm, axis=1)
    w_s = jnp.take_along_axis(w, perm, axis=1)
    valid_s = key_s < E

    # Ragged buffers: src maps ragged row -> group-local token (g = pad
    # row), wr carries the combine weight (0 on pad rows). Row M is the
    # trash row for dropped assignments.
    gi = jnp.broadcast_to(jnp.arange(G)[:, None], (G, N))
    src = jnp.full((G, M + 1), g, jnp.int32).at[gi, dest].set(tok_s)[:, :M]
    wr = (
        jnp.zeros((G, M + 1), w_s.dtype)
        .at[gi, dest].set(jnp.where(valid_s, w_s, 0.0))[:, :M]
    )

    gm = jnp.broadcast_to(jnp.arange(G)[:, None], (G, M))
    pad_row = src >= g
    xs = xg[gm, jnp.minimum(src, g - 1)]
    xs = xs * (1.0 - pad_row[..., None].astype(xg.dtype))
    # Ragged rows stay batch-sharded: expert boundaries are dynamic, so
    # the expert dim cannot be a sharding axis here (see module docstring).
    xs = act(ctx, xs, "batch seq embed")
    wi, wg, wo = _compute_layout_weights(params["experts"], ctx)
    ys = ops.grouped_mlp(
        xs, wi, wg, wo, counts,
        act=cfg.act, block=block, implementation=implementation,
    )
    # Combine: weight, unsort, scatter-add (duplicate token rows — one per
    # surviving assignment — accumulate, exactly like the gather path).
    ys = act(ctx, ys, "batch seq mlp")
    yw = (ys * wr[..., None]).astype(xg.dtype)
    y = jnp.zeros((G, g + 1, d), xg.dtype)
    y = act(ctx, y, "batch seq mlp")
    y = y.at[gm, src].add(yw)
    y = act(ctx, y, "batch seq mlp")
    return y[:, :g]


def _group(x2d: jax.Array, group_size: int):
    n, d = x2d.shape
    g = min(group_size, n)
    pad = (-n) % g
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return x2d.reshape(-1, g, d), n, pad


def moe_apply(
    params,
    x: jax.Array,
    cfg: ArchConfig,
    moe: MoECfg,
    *,
    router_kind: Optional[str] = None,
    dispatch: str = "gather",
    ctx: Optional[ShardCtx] = None,
    implementation: str = "xla",
    sorted_block: int = 128,
    token_mask=None,
):
    """x: (B, S, d) or (N, d). Returns (y, metrics dict).

    ``dispatch``: "einsum" | "gather" (padded capacity buffer) | "sorted"
    (ragged grouped GEMM; ``sorted_block`` is the row-block alignment of
    the ragged buffer — 128 matches the TPU kernel's MXU tiles, tests use
    smaller blocks to keep interpret-mode buffers tiny).

    ``token_mask``: None, or a bool array broadcastable to x's token dims
    (B, S) — False marks dead tokens (the continuous-batching engine's
    free decode slots): they claim no experts, no capacity, and no ragged
    grouped-GEMM rows, so expert compute scales with LIVE tokens rather
    than the static decode batch. Dead tokens' outputs are zero
    (residual passthrough); live tokens are bit-identical to an unmasked
    call with the same group composition.
    """
    router_kind = router_kind or moe.router
    ep_overflow = jnp.zeros((), jnp.float32)
    orig_shape = x.shape
    x2d = x.reshape(-1, x.shape[-1])
    xg, n, pad = _group(x2d, moe.group_size)
    G, g, d = xg.shape

    mg = None
    if token_mask is not None:
        m1 = jnp.broadcast_to(
            token_mask, orig_shape[:-1]
        ).reshape(-1).astype(bool)
        if pad:
            m1 = jnp.pad(m1, (0, pad))
        mg = m1.reshape(G, g)

    logits = jnp.einsum(
        "Ggd,de->Gge", xg, params["router"]["w"],
        preferred_element_type=jnp.float32,
    )
    r = R.route(logits, moe, router_kind, token_mask=mg)
    cap = r.token_idx.shape[-1]

    if dispatch == "einsum":
        # One-hot dispatch/combine (GShard-era faithful path).
        oh = jax.nn.one_hot(r.token_idx, g + 1, dtype=xg.dtype)[..., :g]
        # (G, E, cap, g) x (G, g, d) -> (G, E, cap, d)
        xe = jnp.einsum("Gect,Gtd->Gecd", oh, xg)
        xe = act(ctx, xe, "batch expert cap embed")
        ye = expert_ffn(params["experts"], xe, cfg,
                        implementation=implementation, ctx=ctx)
        ye = act(ctx, ye, "batch expert cap embed")
        comb = oh * r.combine[..., None].astype(xg.dtype)
        y = jnp.einsum("Gect,Gecd->Gtd", comb, ye)
    elif dispatch == "gather":
        safe_idx = jnp.minimum(r.token_idx, g - 1)
        gi = jnp.broadcast_to(
            jnp.arange(G)[:, None, None], r.token_idx.shape
        )
        xe = xg[gi, safe_idx]  # (G, E, cap, d)
        valid = (r.token_idx < g)[..., None].astype(xg.dtype)
        xe = xe * valid
        xe = act(ctx, xe, "batch expert cap embed")
        ye = expert_ffn(params["experts"], xe, cfg,
                        implementation=implementation, ctx=ctx)
        # Combine. Resharding ye from expert-sharded to hidden-sharded
        # BEFORE the scatter makes GSPMD emit a (tokens*k*d/E)-sized
        # all-to-all and a shard-local scatter, instead of partial-summing
        # the full (G, g, d) token buffer with an all-reduce per layer
        # (~E/k * 2 more bytes; see EXPERIMENTS.md SPerf jamba iteration).
        ye = act(ctx, ye, "batch _ cap mlp")
        w = (r.combine[..., None] * valid).astype(ye.dtype)
        yw = (ye * w).astype(xg.dtype)
        y = jnp.zeros((G, g + 1, d), xg.dtype)
        y = act(ctx, y, "batch seq mlp")
        y = y.at[gi, r.token_idx].add(yw)
        y = act(ctx, y, "batch seq mlp")
        y = y[:, :g]
    elif dispatch == "sorted":
        from repro.sharding.logical import expert_parallel_layout

        ep_layout = (
            expert_parallel_layout(ctx.mesh, moe.num_experts)
            if (moe.ep == "a2a" and ctx is not None) else None
        )
        if ep_layout is not None:
            from repro.core.ep import sorted_dispatch_ep

            y, ep_overflow = sorted_dispatch_ep(
                params, xg, r, cfg, moe,
                ctx=ctx, implementation=implementation,
                block=sorted_block,
            )
        else:
            # ep="a2a" on an EP-incapable mesh (or no ctx) falls back to
            # the batch-sharded weight-gather layout — same results.
            y = _sorted_dispatch(
                params, xg, r, cfg, moe,
                ctx=ctx, implementation=implementation,
                block=sorted_block,
            )
    else:
        raise ValueError(f"unknown dispatch {dispatch!r}")

    y = y.reshape(-1, d)
    if pad:
        y = y[:n]
    y = y.reshape(orig_shape).astype(x.dtype)
    # Remat boundary tag: with stack_apply(remat="moe") only this combined
    # output is saved for the backward; the dispatched (G, E, cap, d)
    # buffers and router tensors above are recomputed.
    from jax.ad_checkpoint import checkpoint_name

    y = checkpoint_name(y, "moe_block")

    metrics = {
        "aux_loss": r.aux_loss * moe.aux_loss_weight,
        "z_loss": r.z_loss * moe.z_loss_weight,
        "dropped_frac": r.dropped_frac,
        "router_prob_mean_max": r.probs.max(-1).mean(),
        # Assignments dropped by the expert-parallel a2a send-buffer
        # budget (0 outside the EP path and whenever the budget holds).
        "ep_overflow_frac": ep_overflow,
    }
    return y, metrics

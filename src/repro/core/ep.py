"""Expert-parallel sorted dispatch: shard_map ragged all-to-all.

The ``dispatch="sorted"`` path of core/moe.py keeps the ragged token
buffer batch-sharded and lets GSPMD gather every expert's weights to the
data shards (FSDP / "Llama 3 Meets MoE" layout) — weight traffic scales
with E. This module is the complementary regime (``moe.ep="a2a"``):
**tokens move, weights stay**. Expert weights are sharded over the
``model`` mesh axis (their natural PARAM_RULES placement) and each
device runs the grouped-GEMM kernel over only its E/ep local experts;
token rows cross the axis through two all-to-alls (dispatch + return).

Under ``shard_map`` each device:

1. flattens its local routing groups into one assignment stream and
   stable-partitions it by DESTINATION PEER (``expert // E_loc``);
2. packs rows into a block-aligned send buffer with a *static* per
   (src, dst) row budget — assignments past the budget are dropped
   exactly like capacity overflow (``ep_overflow_frac`` metric);
3. ``lax.all_to_all`` (tiled) exchanges token rows + local-expert ids;
4. locally sorts the received rows by local expert into the same
   block-aligned ragged layout as the single-device sorted path and
   runs ``ops.grouped_mlp`` (Pallas grouped-GEMM kernel / XLA
   ragged_dot — the PR 2 custom-VJP kernels, unchanged);
5. returns results through the mirror all-to-all and combines on the
   SOURCE device (weight multiply + unsort scatter-add), so combine
   weights never travel.

Everything inside the shard_map is plain jnp + ``lax.all_to_all`` +
the custom-VJP grouped kernel, so ``jax.grad`` works end-to-end: the
all-to-alls transpose to all-to-alls, scatters to gathers, and the
replicated-in weight specs transpose to psums over the non-EP axes —
the train loop needs no special casing.

Who moves / where drops happen (vs the other layouts): see the dispatch
table in core/moe.py and kernels/README.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig, MoECfg
from repro.core import routing as R
from repro.sharding import ShardCtx
from repro.sharding.logical import expert_parallel_layout


def ep_row_budget(n_local: int, ep: int, factor: float, block: int) -> int:
    """Static per-(src, dst) peer row budget: ``factor`` times the
    balanced share of the local assignments, block-aligned, capped at
    ``n_local`` (a source can never send more than everything to one
    peer — ``factor >= ep`` therefore guarantees zero EP drops)."""
    b = -(-int(n_local * factor) // ep)
    b = max(block, -(-b // block) * block)
    return min(b, -(-n_local // block) * block)


def sorted_dispatch_ep(
    params, xg, r, cfg: ArchConfig, moe: MoECfg, *,
    ctx: ShardCtx, implementation: str, block: int,
):
    """Expert-parallel sorted dispatch. xg: (G, g, d) -> (y (G, g, d),
    ep_overflow_frac scalar). Caller guarantees
    ``expert_parallel_layout(ctx.mesh, E)`` is not None."""
    from repro.kernels import ops
    from repro.kernels.grouped_mlp import ragged_destinations

    mesh = ctx.mesh
    E = moe.num_experts
    ep_axis, ep, token_axes = expert_parallel_layout(mesh, E)
    E_loc = E // ep
    G, g, d = xg.shape
    ndev = mesh.devices.size
    if G % ndev:
        raise ValueError(
            f"moe.ep='a2a' shards routing groups over all {ndev} mesh "
            f"devices, but G={G} groups (tokens/group_size) is not "
            f"divisible — pick batch*seq and group_size so that "
            f"G % {ndev} == 0"
        )
    tok, eid, w = R.assignment_stream(r, E, g)  # (G, N) each
    N = tok.shape[1]
    G_loc = G // ndev
    n_local = G_loc * N
    budget = ep_row_budget(n_local, ep, moe.ep_budget_factor, block)

    wi = params["experts"]["wi"]
    wg = params["experts"].get("wg")
    wo = params["experts"]["wo"]
    gated = wg is not None

    def local_fn(xg_l, tok_l, eid_l, w_l, *weights):
        if gated:
            wi_l, wg_l, wo_l = weights
        else:
            wi_l, wo_l = weights
            wg_l = None
        Gl = xg_l.shape[0]
        Nl = Gl * N
        f32 = jnp.float32

        # ---- pack by destination peer -------------------------------
        tokf = (
            tok_l + (jnp.arange(Gl, dtype=jnp.int32) * g)[:, None]
        ).reshape(Nl)
        eidf = eid_l.reshape(Nl)
        wf = w_l.reshape(Nl)
        valid = (eidf < E) & (tok_l.reshape(Nl) < g)
        peer = jnp.where(valid, eidf // E_loc, ep).astype(jnp.int32)
        onehot = (
            peer[:, None] == jnp.arange(ep, dtype=jnp.int32)[None, :]
        ).astype(jnp.int32)
        rank = ((jnp.cumsum(onehot, 0) - onehot) * onehot).sum(1)
        keep = valid & (rank < budget)  # overflow dropped like capacity
        slot = jnp.where(keep, peer * budget + rank, ep * budget)

        x_rows = xg_l.reshape(Gl * g, d)[jnp.minimum(tokf, Gl * g - 1)]
        x_rows = x_rows * keep[:, None].astype(x_rows.dtype)
        send_x = (
            jnp.zeros((ep * budget + 1, d), xg_l.dtype)
            .at[slot].set(x_rows)[: ep * budget]
        )
        send_e = (
            jnp.full((ep * budget + 1,), E_loc, jnp.int32)
            .at[slot].set(jnp.where(keep, eidf % E_loc, E_loc))
            [: ep * budget]
        )

        # ---- dispatch all-to-all (tokens + local-expert ids) --------
        recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, ep_axis, 0, 0, tiled=True)

        # ---- local ragged sort by expert + grouped GEMM -------------
        # Same sort-and-pack layout math as the single-device path,
        # shared via kernels/grouped_mlp.py (recv_e == E_loc marks
        # invalid rows; counts (1, E_loc) feeds the kernel directly).
        Rr = ep * budget
        perm, _, counts, dest, M = ragged_destinations(
            recv_e[None], E_loc, block
        )
        perm, dest = perm[0], dest[0]
        xs = (
            jnp.zeros((M + 1, d), xg_l.dtype)
            .at[dest].set(jnp.take(recv_x, perm, axis=0))[:M]
        )
        ys = ops.grouped_mlp(
            xs[None], wi_l, wg_l, wo_l, counts,
            act=cfg.act, block=block, implementation=implementation,
        )[0]

        # ---- return all-to-all + combine on the source --------------
        ys_pad = jnp.concatenate(
            [ys, jnp.zeros((1, d), ys.dtype)], axis=0
        )
        y_recv = (
            jnp.zeros((Rr, d), ys.dtype)
            .at[perm].set(jnp.take(ys_pad, dest, axis=0))
        )
        y_ret = jax.lax.all_to_all(y_recv, ep_axis, 0, 0, tiled=True)
        y_pad = jnp.concatenate(
            [y_ret, jnp.zeros((1, d), y_ret.dtype)], axis=0
        )
        w_eff = jnp.where(keep, wf, 0.0).astype(xg_l.dtype)
        contrib = jnp.take(y_pad, slot, axis=0).astype(xg_l.dtype)
        contrib = contrib * w_eff[:, None]
        tok_dst = jnp.where(keep, tokf, Gl * g)
        y_l = (
            jnp.zeros((Gl * g + 1, d), xg_l.dtype)
            .at[tok_dst].add(contrib)[: Gl * g]
        ).reshape(Gl, g, d)

        # ---- overflow metric (EP drops on top of capacity drops) ----
        n_over = jax.lax.psum(
            jax.lax.stop_gradient((valid & ~keep).sum().astype(f32)),
            token_axes,
        )
        n_valid = jax.lax.psum(
            jax.lax.stop_gradient(valid.sum().astype(f32)), token_axes
        )
        over_frac = n_over / jnp.maximum(n_valid, 1.0)
        return y_l, over_frac

    # Token-side arrays shard their G dim over EVERY mesh axis (each
    # device owns a distinct slice of the routing groups); weights shard
    # experts over the EP axis and replicate over the rest — their
    # transpose under grad is the psum that makes dW globally correct.
    w_spec = P(ep_axis)
    in_specs = [
        P(token_axes, None, None),  # xg
        P(token_axes, None),        # tok
        P(token_axes, None),        # eid
        P(token_axes, None),        # w
        w_spec,                   # wi (E, d, f): experts over ep axis
    ]
    weights = [wi]
    if gated:
        in_specs.append(w_spec)
        weights.append(wg)
    in_specs.append(w_spec)
    weights.append(wo)

    fn = shard_map(
        local_fn, mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(token_axes, None, None), P()),
        check_rep=False,
    )
    return fn(xg, tok, eid, w, *weights)

"""Mamba-1 selective SSM layer (Jamba's mixer; arXiv:2312.00752).

Train/prefill: `lax.scan` over time computing the discretized recurrence
per step (the decay tensor exp(dt*A) is never materialized over T — the
(B, T, d_in, d_state) tensor would be terabytes at Jamba scale). Decode:
single-step state update from (conv_state, ssm_state).

Logical axes put d_inner on the ``model`` mesh axis (tensor parallel), so
per-device states are (B_local, d_in/16, d_state).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import param as pm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return s, d_in, dt_rank


def mamba_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    s, d_in, dt_rank = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    # softplus(dt_bias) spread log-uniform in [1e-3, 1e-1] (mamba init).
    u = jax.random.uniform(ks[4], (d_in,))
    dt = jnp.exp(
        u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    A = jnp.broadcast_to(
        jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (d_in, s.d_state)
    )
    return {
        "in_proj": pm.dense(ks[0], (d, 2 * d_in), "embed mlp", dtype=dtype),
        "conv_w": pm.normal(ks[1], (s.d_conv, d_in), "conv mlp",
                            std=0.02, dtype=dtype),
        "conv_b": pm.zeros((d_in,), "mlp", dtype=dtype),
        "x_proj": pm.dense(
            ks[2], (d_in, dt_rank + 2 * s.d_state), "mlp _", dtype=dtype
        ),
        "dt_w": pm.dense(ks[3], (dt_rank, d_in), "_ mlp", dtype=dtype),
        "dt_b": pm.Param(dt_bias.astype(dtype), "mlp"),
        "A_log": pm.Param(jnp.log(A).astype(dtype), "mlp state"),
        "D": pm.ones((d_in,), "mlp", dtype=dtype),
        "out_proj": pm.dense(ks[5], (d_in, d), "mlp embed", dtype=dtype),
    }


def mamba_cache_init(cfg: ArchConfig, batch: int, *, dtype=jnp.float32):
    s, d_in, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, s.d_state), jnp.float32),
    }


MAMBA_CACHE_AXES = {"conv": "batch conv mlp", "ssm": "batch mlp state"}


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, T, d_in); w: (d_conv, d_in)."""
    d_conv = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],  # (W, I=1, O=d_in) depthwise
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1],
    )
    return out + b


def mamba_apply(p, x, cfg: ArchConfig, *, cache=None, mode="train"):
    """x: (B, T, d). Returns (y, new_cache). mode: train|prefill|decode."""
    s, d_in, dt_rank = _dims(cfg)
    B, T, _ = x.shape
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)

    if mode == "decode":
        # T == 1: roll the conv window.
        assert T == 1
        window = jnp.concatenate([cache["conv"], x_in], axis=1)
        new_conv = window[:, 1:]
        xc = jnp.einsum("btc,tc->bc", window, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)[:, None]  # (B, 1, d_in)
    else:
        xc = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
        new_conv = None
        if mode == "prefill":
            win = s.d_conv - 1
            tail = jnp.pad(x_in, ((0, 0), (max(win - T, 0), 0), (0, 0)))
            new_conv = tail[:, -win:] if win else x_in[:, :0]

    xdb = jnp.einsum("btc,ce->bte", xc, p["x_proj"])
    dt_r = xdb[..., :dt_rank]
    Bm = xdb[..., dt_rank:dt_rank + s.d_state]
    Cm = xdb[..., dt_rank + s.d_state:]
    dt = jax.nn.softplus(
        jnp.einsum("btr,rc->btc", dt_r, p["dt_w"]) + p["dt_b"]
    )  # (B, T, d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (d_in, d_state)

    h0 = (
        cache["ssm"] if cache is not None
        else jnp.zeros((B, d_in, s.d_state), jnp.float32)
    )

    def step(h, xs):
        xc_t, dt_t, B_t, C_t = xs  # (B,d_in),(B,d_in),(B,ds),(B,ds)
        dA = jnp.exp(dt_t[..., None] * A[None])  # (B, d_in, d_state)
        dBx = (dt_t * xc_t)[..., None] * B_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bcs,bs->bc", h, C_t)
        return h, y

    xs = (
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Cm, 1, 0).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B, T, d_in)
    y = y + p["D"] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("btc,cd->btd", y, p["out_proj"])

    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"conv": new_conv, "ssm": h}
    return out, new_cache

"""Top-level model builders: init / train-forward / loss / prefill / decode
for all architecture families (decoder-only LM, encoder-decoder, encoder-
only ViT), selected purely by ``ArchConfig``.

Batch formats
  decoder_only : {"tokens": (B,S) i32, "targets": (B,S) i32}
                 (+ "patch_embeds": (B,P,d) f for vlm frontends)
  encoder_decoder: {"enc_tokens": (B,Se) i32 | "frames": (B,Se,d) f,
                    "dec_tokens": (B,Sd), "targets": (B,Sd)}
  encoder_only : {"patch_embeds": (B,P,d), "labels": (B,) i32}

Targets use -1 for masked-out positions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import param as pm
from repro.models import stack as stk
from repro.models.layers import (
    embed_apply,
    embed_init,
    frontend_apply,
    frontend_init,
    head_apply,
    head_init,
    norm_apply,
    norm_init,
)
from repro.sharding import ShardCtx, act


@dataclasses.dataclass(frozen=True)
class ApplyCfg:
    """Runtime knobs (everything static at trace time).

    The kernel implementation knobs (moe_impl, attn_impl) default to
    "auto": fused Pallas kernels — forward AND custom-VJP backward — on
    TPU, XLA einsums on CPU. ``resolve()`` pins "auto" to a concrete
    backend at trace time.
    """

    dispatch: str = "gather"  # moe dispatch: gather | einsum | sorted
    # Row-block alignment of the sorted dispatch's ragged buffer. 128
    # matches the grouped-GEMM kernel's MXU tiles (training / TPU); the
    # layout guarantees >= 1 block per expert, so tiny decode batches
    # want a small block (the serve engine picks 8 on the XLA backend —
    # E*128 floor rows for a 16-assignment decode batch otherwise).
    sorted_block: int = 128
    moe_impl: str = "auto"  # auto | xla | pallas | ref
    attn_impl: str = "auto"  # auto | xla | pallas | ref
    mixer_impl: str = "xla"
    remat: str = "none"  # none | full | dots | moe
    compute_dtype: str = "float32"  # float32 | bfloat16
    # Chunked cross-entropy: compute logits+CE in seq chunks under remat so
    # the (B, S, V) logits tensor is never materialized (0 = full logits;
    # beyond-paper memory optimization, see EXPERIMENTS.md SPerf).
    ce_chunk: int = 0
    # Zero-pad attention heads to a multiple of this so indivisible head
    # counts still tensor-parallel shard (0 = off; see models/attention).
    pad_heads_multiple: int = 0

    @property
    def cdtype(self):
        return jnp.bfloat16 if self.compute_dtype == "bfloat16" else jnp.float32

    def resolve(self) -> "ApplyCfg":
        """Pin "auto" impls to the backend default (pallas on TPU, xla on
        CPU). Idempotent; called at every model entry point."""
        from repro.kernels.ops import default_implementation

        if self.moe_impl != "auto" and self.attn_impl != "auto":
            return self
        default = default_implementation()
        return dataclasses.replace(
            self,
            moe_impl=default if self.moe_impl == "auto" else self.moe_impl,
            attn_impl=(
                default if self.attn_impl == "auto" else self.attn_impl
            ),
        )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    """Returns a wrapped (Param-leaf) tree."""
    ks = jax.random.split(rng, 8)
    p = {}
    if cfg.structure == "encoder_only":
        p["frontend"] = frontend_init(ks[0], cfg, dtype=dtype)
        p["pos"] = pm.normal(
            ks[1], (cfg.n_frontend_positions, cfg.d_model), "pos embed",
            std=0.02, dtype=dtype,
        )
        p["stack"] = stk.stack_init(
            ks[2], cfg, stk.layer_descs(cfg, stack="decoder"), dtype=dtype
        )
        p["final_norm"] = norm_init(cfg)
        p["head"] = {
            "w": pm.dense(ks[3], (cfg.d_model, cfg.vocab_size),
                          "embed vocab", dtype=dtype)
        }
        return p

    p["embed"] = embed_init(ks[0], cfg, dtype=dtype)
    if cfg.frontend is not None:
        p["frontend"] = frontend_init(ks[1], cfg, dtype=dtype)
    if cfg.structure == "encoder_decoder":
        p["encoder"] = stk.stack_init(
            ks[2], cfg, stk.layer_descs(cfg, stack="encoder"), dtype=dtype
        )
        p["enc_final_norm"] = norm_init(cfg)
    p["stack"] = stk.stack_init(
        ks[3], cfg, stk.layer_descs(cfg, stack="decoder"), dtype=dtype
    )
    p["final_norm"] = norm_init(cfg)
    p["head"] = head_init(ks[4], cfg, dtype=dtype)
    return p


def _cast_params(params, dtype):
    """Mixed precision: compute with a low-precision view of the weights
    (grads flow through the cast back to the fp32 masters)."""
    return jax.tree.map(
        lambda p: p.astype(dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )


# ---------------------------------------------------------------------------
# forward (train / eval)
# ---------------------------------------------------------------------------


def _embed_decoder_input(params, batch, cfg: ArchConfig, ac: ApplyCfg):
    tokens = batch["tokens"] if "tokens" in batch else batch["dec_tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S)
    x = embed_apply(params["embed"], tokens, cfg, positions=positions)
    if cfg.frontend is not None and "patch_embeds" in batch:
        front = frontend_apply(
            params["frontend"], batch["patch_embeds"], cfg
        ).astype(x.dtype)
        n_front = front.shape[1]
        x = jnp.concatenate([front, x[:, n_front:]], axis=1)
    return x.astype(ac.cdtype)


def _encode(params, batch, cfg: ArchConfig, ac: ApplyCfg, ctx):
    """Encoder stack of enc-dec models."""
    if cfg.frontend == "frame":
        x = frontend_apply(params["frontend"], batch["frames"], cfg)
        from repro.models.layers import sinusoidal

        S = x.shape[1]
        x = x + sinusoidal(jnp.arange(S), cfg.d_model).astype(x.dtype)
    else:
        S = batch["enc_tokens"].shape[1]
        x = embed_apply(
            params["embed"], batch["enc_tokens"], cfg,
            positions=jnp.arange(S),
        )
    x = act(ctx, x.astype(ac.cdtype), "batch seq embed")
    x, mets, _ = stk.stack_apply(
        params["encoder"], x, cfg, stk.layer_descs(cfg, stack="encoder"),
        mode="train", causal=False,
        router_kind=stk.stack_router_kind(cfg, stack="encoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat=ac.remat,
    )
    return norm_apply(params["enc_final_norm"], x, cfg), mets


def forward_train(
    params,
    batch,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
    return_hidden: bool = False,
):
    """Returns (logits, metrics); (hidden, metrics) if return_hidden."""
    ac = ac.resolve()
    params = _cast_params(params, ac.cdtype)
    if cfg.structure == "encoder_only":
        x = frontend_apply(params["frontend"], batch["patch_embeds"], cfg)
        x = x + params["pos"][None]
        x = act(ctx, x.astype(ac.cdtype), "batch seq embed")
        x, mets, _ = stk.stack_apply(
            params["stack"], x, cfg,
            stk.layer_descs(cfg, stack="decoder"),
            mode="train", causal=False,
            router_kind=stk.stack_router_kind(cfg, stack="encoder"),
            dispatch=ac.dispatch, sorted_block=ac.sorted_block,
            moe_impl=ac.moe_impl,
            attn_impl=ac.attn_impl,
            mixer_impl=ac.mixer_impl, ctx=ctx, remat=ac.remat,
        )
        x = norm_apply(params["final_norm"], x, cfg)
        pooled = x.mean(axis=1)  # global average pooling (paper §2.2)
        logits = jnp.einsum(
            "bd,dv->bv", pooled, params["head"]["w"]
        ).astype(jnp.float32)
        return logits, mets

    enc = None
    enc_mets = stk.zero_metrics()
    if cfg.structure == "encoder_decoder":
        enc, enc_mets = _encode(params, batch, cfg, ac, ctx)

    x = _embed_decoder_input(params, batch, cfg, ac)
    x = act(ctx, x, "batch seq embed")
    x, mets, _ = stk.stack_apply(
        params["stack"], x, cfg, stk.layer_descs(cfg, stack="decoder"),
        enc=enc, mode="train", causal=True,
        router_kind=stk.stack_router_kind(cfg, stack="decoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat=ac.remat,
    )
    x = norm_apply(params["final_norm"], x, cfg)
    mets = jax.tree.map(jnp.add, mets, enc_mets)
    if return_hidden:
        return x, mets
    logits = head_apply(
        params.get("head", {}), x, params["embed"], cfg
    ).astype(jnp.float32)
    logits = act(ctx, logits, "batch seq vocab")
    return logits, mets


def loss_fn(
    params,
    batch,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
):
    """Returns (loss, metrics-dict). CE + weighted MoE aux losses."""
    if cfg.structure == "encoder_only":
        logits, mets = forward_train(params, batch, cfg, ac=ac, ctx=ctx)
        labels = batch["labels"]
        ce = -jnp.mean(
            jnp.take_along_axis(
                jax.nn.log_softmax(logits), labels[:, None], axis=-1
            )
        )
    elif ac.ce_chunk:
        hidden, mets = forward_train(
            params, batch, cfg, ac=ac, ctx=ctx, return_hidden=True
        )
        w = (
            params["embed"]["tokens"].T
            if cfg.tie_embeddings
            else params["head"]["w"]
        ).astype(ac.cdtype)
        ce = _chunked_ce(hidden, w, batch["targets"], ac.ce_chunk)
    else:
        logits, mets = forward_train(params, batch, cfg, ac=ac, ctx=ctx)
        targets = batch["targets"]
        valid = targets >= 0
        tgt = jnp.maximum(targets, 0)
        logp = jax.nn.log_softmax(logits)
        ce_tok = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(valid.sum(), 1)
        ce = jnp.where(valid, ce_tok, 0.0).sum() / denom
    loss = ce + mets["aux_loss"] + mets["z_loss"]
    out = dict(mets)
    out.update(loss=loss, ce=ce)
    return loss, out


def _chunked_ce(hidden, w, targets, chunk: int):
    """CE over seq chunks with per-chunk logits rematerialization.

    hidden: (B, S, d); w: (d, V); targets: (B, S) with -1 = masked.
    Never materializes (B, S, V): each chunk computes its logits, reduces
    to per-token CE, and the backward pass recomputes them (jax.checkpoint
    around the chunk body).
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)),
                          constant_values=-1)
    nc = (S + pad) // chunk
    hc = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        ce_sum, n = carry
        xch, tch = xs
        logits = jnp.einsum(
            "bsd,dv->bsv", xch, w, preferred_element_type=jnp.float32
        )
        valid = tch >= 0
        tgt = jnp.maximum(tch, 0)
        logp = jax.nn.log_softmax(logits)
        ce_tok = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        ce_sum = ce_sum + jnp.where(valid, ce_tok, 0.0).sum()
        n = n + valid.sum()
        return (ce_sum, n), None

    (ce_sum, n), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (hc, tc)
    )
    return ce_sum / jnp.maximum(n, 1)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_serve_cache(
    cfg: ArchConfig, batch: int, max_len: int, *, dtype=jnp.bfloat16,
    enc_len: int = 0,
):
    descs = stk.layer_descs(cfg, stack="decoder")
    cache = {"stack": stk.stack_cache_init(cfg, descs, batch, max_len,
                                           dtype=dtype)}
    if cfg.structure == "encoder_decoder":
        cache["enc"] = jnp.zeros((batch, enc_len, cfg.d_model), dtype)
    return cache


def init_paged_serve_cache(
    cfg: ArchConfig, num_blocks: int, block_size: int, *,
    dtype=jnp.bfloat16,
):
    """Paged serve cache: per-layer KV block pools addressed by shared
    per-slot block tables (repro/serve continuous-batching engine).

    Paged serving is decoder-only + attention-only: encoder-decoder
    models carry a dense encoder cache and mamba/rwkv6 mixers keep
    per-slot state vectors with no seq dim to page — both raise here
    (serve them through the static-batch engine instead)."""
    if cfg.structure != "decoder_only":
        raise ValueError(
            "paged serving supports decoder-only models; "
            f"{cfg.name} is {cfg.structure}"
        )
    descs = stk.layer_descs(cfg, stack="decoder")
    if any(d.mixer != "attn" for d in descs):
        raise ValueError(
            "paged serving requires an attention-only decoder stack "
            f"(got {sorted({d.mixer for d in descs})} in {cfg.name})"
        )
    return {
        "stack": stk.stack_paged_cache_init(
            cfg, descs, num_blocks, block_size, dtype=dtype
        )
    }


def paged_prefill(
    params,
    tokens,
    cache,
    block_table,
    length,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
):
    """Prefill ONE request into its freshly allocated KV blocks
    (continuous batching's prefill-on-join).

    tokens: (1, Sp) right-padded prompt with Sp a multiple of the block
    size (the engine buckets prompt lengths — padded tail k/v land in
    the slot's own blocks and stay masked by ``length`` until decode
    overwrites them); block_table: (1, nb) pool block ids; length:
    traced int32 true prompt length. Returns (cache, logits (1, 1, V))
    — the logits at the TRUE last prompt position (length - 1), not the
    padded one.
    """
    ac = ac.resolve()
    params = _cast_params(params, ac.cdtype)
    x = _embed_decoder_input(params, {"tokens": tokens}, cfg, ac)
    x = act(ctx, x, "batch seq embed")
    x, _, stack_cache = stk.stack_apply(
        params["stack"], x, cfg, stk.layer_descs(cfg, stack="decoder"),
        cache=cache["stack"],
        cache_index=jnp.zeros((1,), jnp.int32),
        block_tables=block_table,
        mode="prefill", causal=True,
        router_kind=stk.stack_router_kind(cfg, stack="decoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat="none",
    )
    new_cache = dict(cache)
    new_cache["stack"] = stack_cache
    x_last = jax.lax.dynamic_slice_in_dim(
        x, jnp.asarray(length, jnp.int32) - 1, 1, axis=1
    )
    x_last = norm_apply(params["final_norm"], x_last, cfg)
    logits = head_apply(
        params.get("head", {}), x_last, params.get("embed"), cfg
    ).astype(jnp.float32)
    return new_cache, logits


def paged_decode_step(
    params,
    tokens,
    cache,
    block_tables,
    lengths,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
):
    """One continuous-batching decode step over the slot batch.

    tokens: (B, 1) current token per slot; block_tables: (B, nb);
    lengths: (B,) int32 tokens already cached per slot — 0 marks a FREE
    slot: its token is masked out of MoE routing (no capacity claims,
    no grouped-GEMM rows — expert compute scales with live slots), its
    cache write lands in the trash block, and its logits are garbage the
    engine never samples. Returns (cache, logits (B, 1, V)).
    """
    ac = ac.resolve()
    params = _cast_params(params, ac.cdtype)
    live = lengths > 0
    x = embed_apply(
        params["embed"], tokens, cfg, positions=lengths[:, None]
    ).astype(ac.cdtype)
    x = act(ctx, x, "batch seq embed")
    x, _, stack_cache = stk.stack_apply(
        params["stack"], x, cfg, stk.layer_descs(cfg, stack="decoder"),
        cache=cache["stack"], cache_index=lengths,
        block_tables=block_tables,
        token_mask=live[:, None],
        mode="decode", causal=True,
        router_kind=stk.stack_router_kind(cfg, stack="decoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat="none",
    )
    new_cache = dict(cache)
    new_cache["stack"] = stack_cache
    x = norm_apply(params["final_norm"], x, cfg)
    logits = head_apply(
        params.get("head", {}), x, params.get("embed"), cfg
    ).astype(jnp.float32)
    return new_cache, logits


def paged_mixed_step(
    params,
    dec_tokens,
    chunk_tokens,
    cache,
    dec_tables,
    dec_lengths,
    chunk_tables,
    chunk_starts,
    chunk_lens,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
):
    """One fused continuous-batching step: the decode batch AND the
    pending prefill chunks through a SINGLE forward (one jit signature
    per engine — no per-admission B=1 prefill, no bucketed-length
    compile zoo).

    dec_tokens: (B, 1) current token per decode slot; dec_lengths: (B,)
    tokens already cached (0 = slot free or still prefilling -> masked
    out of routing, write lands in the trash block); dec_tables: (B, nb)
    — rows of non-decoding slots must be zeroed by the engine.
    chunk_tokens: (NC, C) — NC chunk lanes of C consecutive prompt
    tokens each; chunk_tables: (NC, nb) the owning slot's block table;
    chunk_starts: (NC,) absolute position of the chunk's first token;
    chunk_lens: (NC,) valid tokens in the lane (0 = idle lane).

    The row batch is R = B + NC*C single-token rows. All rows write
    their k/v through one paged scatter; decode rows read via the paged
    flash-decode kernel, chunk rows via the paged prefill kernel
    (models/attention mixed mode). MoE routes with dead rows masked, so
    expert FLOPs track live tokens: decode rows ride the live-token
    sorted dispatch, chunk rows keep expert work dense.

    Returns ``(cache, logits (B + NC, V))``: rows [:B] are the decode
    slots' next-token logits, rows [B:] each chunk lane's logits at its
    LAST valid row — the engine samples a request's first token from
    them when a chunk completes the prompt. One array so the engine
    pays ONE host sync per mixed step.
    """
    ac = ac.resolve()
    params = _cast_params(params, ac.cdtype)
    B = dec_tokens.shape[0]
    NC, C = chunk_tokens.shape
    dec_lengths = dec_lengths.astype(jnp.int32)
    chunk_starts = chunk_starts.astype(jnp.int32)
    chunk_lens = chunk_lens.astype(jnp.int32)
    dec_live = dec_lengths > 0
    chunk_live = jnp.arange(C)[None, :] < chunk_lens[:, None]  # (NC, C)
    tokens = jnp.concatenate(
        [dec_tokens.reshape(B), chunk_tokens.reshape(NC * C)]
    )[:, None].astype(jnp.int32)  # (R, 1)
    positions = jnp.concatenate([
        dec_lengths,
        (chunk_starts[:, None] + jnp.arange(C)[None, :]).reshape(NC * C),
    ]).astype(jnp.int32)  # (R,)
    row_tables = jnp.concatenate(
        [dec_tables, jnp.repeat(chunk_tables, C, axis=0)], axis=0
    ).astype(jnp.int32)  # (R, nb)
    token_mask = jnp.concatenate(
        [dec_live, chunk_live.reshape(NC * C)]
    )[:, None]
    from repro.models.attention import MixedMeta

    x = embed_apply(
        params["embed"], tokens, cfg, positions=positions[:, None]
    ).astype(ac.cdtype)
    x = act(ctx, x, "batch seq embed")
    x, _, stack_cache = stk.stack_apply(
        params["stack"], x, cfg, stk.layer_descs(cfg, stack="decoder"),
        cache=cache["stack"], cache_index=positions,
        block_tables=row_tables,
        token_mask=token_mask,
        mixed=MixedMeta(
            num_decode=B, num_chunks=NC, chunk_tokens=C,
            chunk_lens=chunk_lens,
        ),
        mode="decode", causal=True,
        router_kind=stk.stack_router_kind(cfg, stack="decoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat="none",
    )
    new_cache = dict(cache)
    new_cache["stack"] = stack_cache
    # Head only over the rows the engine samples: the B decode rows plus
    # each chunk lane's last valid row (the TRUE last prompt position
    # when the chunk completes a prompt).
    d = x.shape[-1]
    xd = x[:B, 0]
    last = jnp.clip(chunk_lens - 1, 0, C - 1)
    xc = x[B:, 0].reshape(NC, C, d)[jnp.arange(NC), last]
    h = jnp.concatenate([xd, xc], axis=0)[:, None]  # (B + NC, 1, d)
    h = norm_apply(params["final_norm"], h, cfg)
    logits = head_apply(
        params.get("head", {}), h, params.get("embed"), cfg
    ).astype(jnp.float32)
    return new_cache, logits[:, 0]


def paged_verify_step(
    params,
    verify_tokens,
    chunk_tokens,
    cache,
    verify_tables,
    verify_starts,
    verify_lens,
    chunk_tables,
    chunk_starts,
    chunk_lens,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
):
    """One fused speculative-verify + chunked-prefill step: the target
    model scores B verify lanes of K1 = k+1 positions each (the slot's
    pending token plus its k drafted tokens) AND the pending prefill
    chunks through a SINGLE forward (one jit signature per engine).

    verify_tokens: (B, K1) per slot [pending, d_1..d_k] right-padded;
    verify_tables: (B, nb) the slot's block table (zeroed for slots not
    verifying); verify_starts: (B,) tokens already cached (the pending
    token's write position); verify_lens: (B,) valid rows per lane,
    1 + k_eff, 0 = slot idle this tick. chunk_*: exactly as in
    :func:`paged_mixed_step`.

    The row batch is R = B*K1 + NC*C single-token rows, all sharing the
    one paged k/v scatter; verify rows read via the paged prefill
    kernel (row j attends positions <= starts + j), so verification IS
    a chunk-lane pass over already-drafted tokens. Dead rows (beyond
    verify_lens, idle lanes) scatter to the trash block and are masked
    out of MoE routing — rejected drafts leak no pool state because the
    engine simply rewinds ``slot.length``; stale rows past the new
    length are never attended and get overwritten by later writes.

    Returns ``(cache, logits (B*K1 + NC, V))``: rows [:B*K1] are the
    target logits at EVERY verify position (row b*K1 + j scores the
    token following verify_tokens[b, j]), rows [B*K1:] each chunk
    lane's last-valid-row logits. One array, one host sync per step.
    """
    ac = ac.resolve()
    params = _cast_params(params, ac.cdtype)
    B, K1 = verify_tokens.shape
    NC, C = chunk_tokens.shape
    verify_starts = verify_starts.astype(jnp.int32)
    verify_lens = verify_lens.astype(jnp.int32)
    chunk_starts = chunk_starts.astype(jnp.int32)
    chunk_lens = chunk_lens.astype(jnp.int32)
    ver_live = jnp.arange(K1)[None, :] < verify_lens[:, None]  # (B, K1)
    chunk_live = jnp.arange(C)[None, :] < chunk_lens[:, None]  # (NC, C)
    tokens = jnp.concatenate(
        [verify_tokens.reshape(B * K1), chunk_tokens.reshape(NC * C)]
    )[:, None].astype(jnp.int32)  # (R, 1)
    positions = jnp.concatenate([
        (verify_starts[:, None] + jnp.arange(K1)[None, :]).reshape(B * K1),
        (chunk_starts[:, None] + jnp.arange(C)[None, :]).reshape(NC * C),
    ]).astype(jnp.int32)  # (R,)
    row_tables = jnp.concatenate([
        jnp.repeat(verify_tables, K1, axis=0),
        jnp.repeat(chunk_tables, C, axis=0),
    ], axis=0).astype(jnp.int32)  # (R, nb)
    token_mask = jnp.concatenate(
        [ver_live.reshape(B * K1), chunk_live.reshape(NC * C)]
    )[:, None]
    from repro.models.attention import MixedMeta

    x = embed_apply(
        params["embed"], tokens, cfg, positions=positions[:, None]
    ).astype(ac.cdtype)
    x = act(ctx, x, "batch seq embed")
    x, _, stack_cache = stk.stack_apply(
        params["stack"], x, cfg, stk.layer_descs(cfg, stack="decoder"),
        cache=cache["stack"], cache_index=positions,
        block_tables=row_tables,
        token_mask=token_mask,
        mixed=MixedMeta(
            num_decode=0, num_chunks=NC, chunk_tokens=C,
            chunk_lens=chunk_lens,
            num_verify=B, verify_tokens=K1, verify_lens=verify_lens,
        ),
        mode="decode", causal=True,
        router_kind=stk.stack_router_kind(cfg, stack="decoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat="none",
    )
    new_cache = dict(cache)
    new_cache["stack"] = stack_cache
    # Head over ALL verify rows (the engine needs the target
    # distribution at every drafted position for rejection sampling)
    # plus each chunk lane's last valid row.
    d = x.shape[-1]
    xv = x[: B * K1, 0]
    last = jnp.clip(chunk_lens - 1, 0, C - 1)
    xc = x[B * K1:, 0].reshape(NC, C, d)[jnp.arange(NC), last]
    h = jnp.concatenate([xv, xc], axis=0)[:, None]  # (B*K1 + NC, 1, d)
    h = norm_apply(params["final_norm"], h, cfg)
    logits = head_apply(
        params.get("head", {}), h, params.get("embed"), cfg
    ).astype(jnp.float32)
    return new_cache, logits[:, 0]


def serve_cache_axes(cfg: ArchConfig):
    descs = stk.layer_descs(cfg, stack="decoder")
    axes = {"stack": stk.stack_cache_axes(descs)}
    if cfg.structure == "encoder_decoder":
        axes["enc"] = "batch seq embed"
    return axes


def prefill(
    params,
    batch,
    cache,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
):
    """Run the full prompt, writing caches. Returns (cache, last_logits)."""
    ac = ac.resolve()
    params = _cast_params(params, ac.cdtype)
    enc = None
    if cfg.structure == "encoder_decoder":
        enc, _ = _encode(params, batch, cfg, ac, ctx)
        cache = dict(cache)
        cache["enc"] = enc.astype(cache["enc"].dtype)
    x = _embed_decoder_input(params, batch, cfg, ac)
    x = act(ctx, x, "batch seq embed")
    x, _, stack_cache = stk.stack_apply(
        params["stack"], x, cfg, stk.layer_descs(cfg, stack="decoder"),
        enc=enc, cache=cache["stack"], cache_index=jnp.asarray(0, jnp.int32),
        mode="prefill", causal=True,
        router_kind=stk.stack_router_kind(cfg, stack="decoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat=ac.remat,
    )
    new_cache = dict(cache)
    new_cache["stack"] = stack_cache
    x = norm_apply(params["final_norm"], x[:, -1:], cfg)
    logits = head_apply(
        params.get("head", {}), x, params.get("embed"), cfg
    ).astype(jnp.float32)
    return new_cache, logits


def decode_step(
    params,
    tokens,
    cache,
    cache_index,
    cfg: ArchConfig,
    *,
    ac: ApplyCfg = ApplyCfg(),
    ctx: Optional[ShardCtx] = None,
):
    """One autoregressive step. tokens: (B, 1). Returns (cache, logits)."""
    ac = ac.resolve()
    params = _cast_params(params, ac.cdtype)
    enc = cache.get("enc") if cfg.structure == "encoder_decoder" else None
    x = embed_apply(
        params["embed"], tokens, cfg,
        positions=cache_index + jnp.arange(1),
    ).astype(ac.cdtype)
    x, _, stack_cache = stk.stack_apply(
        params["stack"], x, cfg, stk.layer_descs(cfg, stack="decoder"),
        enc=None if enc is None else enc.astype(ac.cdtype),
        cache=cache["stack"], cache_index=cache_index,
        mode="decode", causal=True,
        router_kind=stk.stack_router_kind(cfg, stack="decoder"),
        dispatch=ac.dispatch, sorted_block=ac.sorted_block,
        moe_impl=ac.moe_impl,
        attn_impl=ac.attn_impl,
        mixer_impl=ac.mixer_impl,
        pad_heads_multiple=ac.pad_heads_multiple,
        ctx=ctx, remat="none",
    )
    new_cache = dict(cache)
    new_cache["stack"] = stack_cache
    x = norm_apply(params["final_norm"], x, cfg)
    logits = head_apply(
        params.get("head", {}), x, params.get("embed"), cfg
    ).astype(jnp.float32)
    return new_cache, logits

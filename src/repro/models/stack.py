"""Layer stacks: descriptors, segment (superblock) detection, scan-over-
layers application.

A stack is a list of ``LayerDesc`` (mixer x ffn x cross). Heterogeneous
layouts (jamba's 1-attn:7-mamba, paper's every-other MoE, last-half MoE)
are factored into *segments*: maximal runs with a repeating period. Params
of each segment position are stacked over repeats and applied with
``lax.scan`` — one traced layer body per position regardless of depth, so
a 72-layer jamba compiles as one 8-position superblock scanned 9 times.

The same desc machinery drives the upcycling surgery (core/upcycle.py):
dense parent and sparse target enumerate layers identically, so parameter
mapping is positional and exact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, MoECfg
from repro.core.moe import moe_apply, moe_init
from repro.models import param as pm
from repro.models import rwkv, ssm
from repro.models.attention import (
    CACHE_AXES,
    attention_apply,
    attention_init,
    init_cache as attn_cache_init,
)
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init
from repro.sharding import ShardCtx


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    mixer: str  # attn | mamba | rwkv6
    ffn: str  # dense | moe
    cross: bool = False


def layer_descs(cfg: ArchConfig, *, stack: str = "decoder") -> list[LayerDesc]:
    n = cfg.n_encoder_layers if stack == "encoder" else cfg.n_layers
    cross = stack == "decoder" and cfg.structure == "encoder_decoder"
    descs = []
    for l in range(n):
        if stack == "encoder" or cfg.attn_pattern == "all":
            mixer = "attn"
        elif cfg.attn_pattern == "none":
            mixer = "rwkv6"
        elif cfg.attn_pattern == "jamba":
            mixer = "attn" if l % 8 == 4 else "mamba"
        else:
            raise ValueError(cfg.attn_pattern)
        ffn = "dense"
        if cfg.moe is not None:
            pat = cfg.moe.layer_pattern
            if pat == "all":
                ffn = "moe"
            elif pat == "every_other":
                ffn = "moe" if l % 2 == 1 else "dense"
            elif pat == "last_half":
                ffn = "moe" if l >= n - n // 2 else "dense"
            elif pat != "none":
                raise ValueError(pat)
        descs.append(LayerDesc(mixer=mixer, ffn=ffn, cross=cross))
    return descs


def stack_router_kind(cfg: ArchConfig, *, stack: str) -> str:
    """Paper §3.1: Expert Choice in encoders, Top-K in decoders."""
    if cfg.moe is None:
        return "top_k"
    if stack == "decoder" and cfg.moe.router == "expert_choice":
        return "top_k"
    return cfg.moe.router


def find_segments(descs: list[LayerDesc]) -> list[tuple[int, list[LayerDesc]]]:
    """-> [(repeats, period_descs), ...]; greedy smallest-period split."""
    n = len(descs)
    if n == 0:
        return []
    for p in range(1, n + 1):
        if n % p:
            continue
        if all(descs[i] == descs[i % p] for i in range(n)):
            return [(n // p, descs[:p])]
    half = n // 2
    return find_segments(descs[:half]) + find_segments(descs[half:])


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def layer_init(rng, cfg: ArchConfig, desc: LayerDesc, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 6)
    p = {"pre_norm": norm_init(cfg)}
    if desc.mixer == "attn":
        p["mixer"] = attention_init(ks[0], cfg, dtype=dtype)
    elif desc.mixer == "mamba":
        p["mixer"] = ssm.mamba_init(ks[0], cfg, dtype=dtype)
    elif desc.mixer == "rwkv6":
        p["mixer"] = rwkv.time_mix_init(ks[0], cfg, dtype=dtype)
    else:
        raise ValueError(desc.mixer)
    if desc.cross:
        p["cross_norm"] = norm_init(cfg)
        p["cross"] = attention_init(ks[1], cfg, dtype=dtype)
    p["ffn_norm"] = norm_init(cfg)
    if desc.mixer == "rwkv6":
        p["cm"] = rwkv.channel_mix_init(ks[2], cfg, dtype=dtype)
    if desc.ffn == "moe":
        p["ffn"] = moe_init(ks[3], cfg, cfg.moe, dtype=dtype)
    else:
        p["ffn"] = mlp_init(ks[3], cfg, dtype=dtype)
    return p


def layer_cache_init(
    cfg: ArchConfig, desc: LayerDesc, batch: int, max_len: int,
    *, dtype=jnp.bfloat16
):
    c = {}
    if desc.mixer == "attn":
        c["mixer"] = attn_cache_init(cfg, batch, max_len, dtype=dtype)
    elif desc.mixer == "mamba":
        c["mixer"] = ssm.mamba_cache_init(cfg, batch, dtype=dtype)
    elif desc.mixer == "rwkv6":
        c["mixer"] = rwkv.time_mix_cache_init(cfg, batch, dtype=dtype)
        c["cm"] = rwkv.channel_mix_cache_init(cfg, batch, dtype=dtype)
    return c


def layer_paged_cache_init(
    cfg: ArchConfig, desc: LayerDesc, num_blocks: int, block_size: int,
    *, dtype=jnp.bfloat16
):
    """Per-layer KV block pool for the paged serving engine. Paged
    serving is attention-only: mamba/rwkv6 caches are per-slot state
    vectors with no seq dim (nothing to page) — the serve engine rejects
    those stacks up front (see repro/serve)."""
    if desc.mixer != "attn":
        raise ValueError(
            f"paged serving supports attention mixers only, got "
            f"{desc.mixer!r}"
        )
    from repro.models.attention import init_paged_cache

    return {"mixer": init_paged_cache(cfg, num_blocks, block_size,
                                      dtype=dtype)}


def layer_cache_axes(desc: LayerDesc):
    c = {}
    if desc.mixer == "attn":
        c["mixer"] = dict(CACHE_AXES)
    elif desc.mixer == "mamba":
        c["mixer"] = dict(ssm.MAMBA_CACHE_AXES)
    elif desc.mixer == "rwkv6":
        c["mixer"] = dict(rwkv.TIME_MIX_CACHE_AXES)
        c["cm"] = dict(rwkv.CHANNEL_MIX_CACHE_AXES)
    return c


def zero_metrics():
    return {
        "aux_loss": jnp.zeros((), jnp.float32),
        "z_loss": jnp.zeros((), jnp.float32),
        "dropped_frac_sum": jnp.zeros((), jnp.float32),
        "moe_layer_count": jnp.zeros((), jnp.float32),
    }


def layer_apply(
    p,
    x,
    cfg: ArchConfig,
    desc: LayerDesc,
    *,
    enc=None,
    cache=None,
    cache_index=None,
    mode: str = "train",
    causal: bool = True,
    router_kind: str = "top_k",
    dispatch: str = "gather",
    sorted_block: int = 128,
    moe_impl: str = "xla",
    mixer_impl: str = "xla",
    attn_impl: str = "xla",
    pad_heads_multiple: int = 0,
    ctx: Optional[ShardCtx] = None,
    block_tables=None,
    token_mask=None,
    mixed=None,
):
    cache = cache or None
    mix_cache = cache.get("mixer") if cache else None
    h = norm_apply(p["pre_norm"], x, cfg)
    if desc.mixer == "attn":
        y, mix_cache = attention_apply(
            p["mixer"], h, cfg,
            causal=causal,
            cache=mix_cache,
            cache_index=cache_index,
            ctx=ctx,
            pad_heads_multiple=pad_heads_multiple,
            implementation=attn_impl,
            block_tables=block_tables,
            mixed=mixed,
        )
    elif desc.mixer == "mamba":
        y, mix_cache = ssm.mamba_apply(
            p["mixer"], h, cfg, cache=mix_cache, mode=mode
        )
    else:
        y, mix_cache = rwkv.time_mix_apply(
            p["mixer"], h, cfg, cache=mix_cache, mode=mode,
            implementation=mixer_impl,
        )
    x = x + y

    if desc.cross:
        hc = norm_apply(p["cross_norm"], x, cfg)
        yc, _ = attention_apply(
            p["cross"], hc, cfg, kv_x=enc, causal=False, ctx=ctx,
            pad_heads_multiple=pad_heads_multiple,
            implementation=attn_impl,
        )
        x = x + yc

    h = norm_apply(p["ffn_norm"], x, cfg)
    gate = None
    cm_cache = None
    if "cm" in p:
        h, gate, cm_cache = rwkv.channel_mix_pre(
            p["cm"], h, cache=cache.get("cm") if cache else None
        )

    metrics = zero_metrics()
    if desc.ffn == "moe":
        y, m = moe_apply(
            p["ffn"], h, cfg, cfg.moe,
            router_kind=router_kind,
            dispatch=dispatch,
            sorted_block=sorted_block,
            ctx=ctx,
            implementation=moe_impl,
            token_mask=token_mask,
        )
        metrics["aux_loss"] = m["aux_loss"]
        metrics["z_loss"] = m["z_loss"]
        metrics["dropped_frac_sum"] = m["dropped_frac"]
        metrics["moe_layer_count"] = jnp.ones((), jnp.float32)
    else:
        y = mlp_apply(p["ffn"], h, cfg)
    if gate is not None:
        y = gate * y
    x = x + y

    new_cache = {}
    if cache is not None:
        if mix_cache is not None:
            new_cache["mixer"] = mix_cache
        if cm_cache is not None:
            new_cache["cm"] = cm_cache
    return x, metrics, new_cache


# ---------------------------------------------------------------------------
# Stack init / apply
# ---------------------------------------------------------------------------


def stack_init(rng, cfg: ArchConfig, descs, *, dtype=jnp.float32):
    segs = find_segments(descs)
    out = []
    layer = 0
    for reps, pdescs in segs:
        per_pos = {f"pos{i}": [] for i in range(len(pdescs))}
        for _ in range(reps):
            for i, d in enumerate(pdescs):
                per_pos[f"pos{i}"].append(
                    layer_init(jax.random.fold_in(rng, layer), cfg, d,
                               dtype=dtype)
                )
                layer += 1
        out.append(
            {k: pm.stack_layers(v) for k, v in per_pos.items()}
        )
    return {"segments": out}


def unstack_layers(stack_params, descs):
    """Stacked wrapped params -> ordered list of per-layer wrapped trees."""
    segs = find_segments(descs)
    layers = []
    for si, (reps, pdescs) in enumerate(segs):
        seg = stack_params["segments"][si]
        for r in range(reps):
            for i in range(len(pdescs)):
                layers.append(
                    jax.tree.map(
                        lambda prm, r=r: pm.Param(
                            prm.value[r],
                            prm.axes.split(" ", 1)[1]
                            if " " in prm.axes else "",
                        ),
                        seg[f"pos{i}"],
                        is_leaf=lambda x: isinstance(x, pm.Param),
                    )
                )
    return layers


def restack_layers(layer_trees, descs):
    """Inverse of unstack_layers: per-layer trees -> segment stacks."""
    segs = find_segments(descs)
    out = []
    it = iter(layer_trees)
    for reps, pdescs in segs:
        per_pos = {f"pos{i}": [] for i in range(len(pdescs))}
        for _ in range(reps):
            for i in range(len(pdescs)):
                per_pos[f"pos{i}"].append(next(it))
        out.append({k: pm.stack_layers(v) for k, v in per_pos.items()})
    return {"segments": out}


def stack_cache_init(
    cfg: ArchConfig, descs, batch: int, max_len: int, *, dtype=jnp.bfloat16
):
    segs = find_segments(descs)
    out = []
    for reps, pdescs in segs:
        seg = {}
        for i, d in enumerate(pdescs):
            one = layer_cache_init(cfg, d, batch, max_len, dtype=dtype)
            seg[f"pos{i}"] = jax.tree.map(
                lambda v: jnp.broadcast_to(v, (reps,) + v.shape).copy(), one
            )
        out.append(seg)
    return {"segments": out}


def stack_paged_cache_init(
    cfg: ArchConfig, descs, num_blocks: int, block_size: int, *,
    dtype=jnp.bfloat16
):
    """Paged serve cache: one KV block pool per layer (stacked over
    segment repeats like ``stack_cache_init``); every layer's pool is
    addressed by the SAME per-slot block table (the vLLM layout)."""
    segs = find_segments(descs)
    out = []
    for reps, pdescs in segs:
        seg = {}
        for i, d in enumerate(pdescs):
            one = layer_paged_cache_init(
                cfg, d, num_blocks, block_size, dtype=dtype
            )
            seg[f"pos{i}"] = jax.tree.map(
                lambda v: jnp.broadcast_to(v, (reps,) + v.shape).copy(), one
            )
        out.append(seg)
    return {"segments": out}


def stack_cache_axes(descs):
    segs = find_segments(descs)
    out = []
    for reps, pdescs in segs:
        seg = {}
        for i, d in enumerate(pdescs):
            one = layer_cache_axes(d)
            seg[f"pos{i}"] = jax.tree.map(
                lambda a: ("layer " + a).strip(), one
            )
        out.append(seg)
    return {"segments": out}


def stack_apply(
    params,
    x,
    cfg: ArchConfig,
    descs,
    *,
    enc=None,
    cache=None,
    cache_index=None,
    mode: str = "train",
    causal: bool = True,
    router_kind: str = "top_k",
    dispatch: str = "gather",
    sorted_block: int = 128,
    moe_impl: str = "xla",
    mixer_impl: str = "xla",
    attn_impl: str = "xla",
    pad_heads_multiple: int = 0,
    ctx: Optional[ShardCtx] = None,
    remat: str = "none",  # none | full | dots | moe
    block_tables=None,
    token_mask=None,
    mixed=None,
):
    segs = find_segments(descs)
    totals = zero_metrics()
    new_cache_segs = []

    for si, (reps, pdescs) in enumerate(segs):
        seg_params = params["segments"][si]
        have_cache = cache is not None
        seg_cache = (
            cache["segments"][si]
            if have_cache
            else {f"pos{i}": {} for i in range(len(pdescs))}
        )

        def body(carry, xs, pdescs=pdescs):
            h = carry
            lp, lc = xs
            mets = zero_metrics()
            out_cache = {}
            for i, d in enumerate(pdescs):
                entry = lc.get(f"pos{i}") or None
                h, m, c_new = layer_apply(
                    lp[f"pos{i}"], h, cfg, d,
                    enc=enc,
                    cache=entry,
                    cache_index=cache_index,
                    mode=mode,
                    causal=causal,
                    router_kind=router_kind,
                    dispatch=dispatch,
                    sorted_block=sorted_block,
                    moe_impl=moe_impl,
                    mixer_impl=mixer_impl,
                    attn_impl=attn_impl,
                    pad_heads_multiple=pad_heads_multiple,
                    ctx=ctx,
                    block_tables=block_tables,
                    token_mask=token_mask,
                    mixed=mixed,
                )
                mets = jax.tree.map(jnp.add, mets, m)
                out_cache[f"pos{i}"] = c_new
            return h, (mets, out_cache)

        if remat == "full":
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        elif remat == "moe":
            # MoE-block-boundary remat: save ONLY the combined MoE layer
            # outputs (tagged `moe_block` in core/moe.py). Everything else
            # in the layer — attention activations, dispatched (G, E, cap,
            # d) buffers, router tensors — is recomputed in the backward,
            # so the step's memory high-water mark is set by the Pallas
            # VJP residuals (kernel inputs), not full activations.
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "moe_block"
                ),
            )

        x, (mets, seg_cache_new) = jax.lax.scan(
            body, x, (seg_params, seg_cache)
        )
        totals = jax.tree.map(
            lambda t, m: t + m.sum(), totals, mets
        )
        new_cache_segs.append(seg_cache_new)

    new_cache = {"segments": new_cache_segs} if cache is not None else None
    return x, totals, new_cache

"""Core NN layers: norms, MLPs, embeddings, positional encodings.

All layers follow the init/apply convention from ``repro.models.param``:
``*_init`` returns a wrapped Param tree; ``*_apply`` takes the plain-array
tree. Logical axis names (see repro/sharding/logical.py): embed, mlp, heads,
kv_heads, head_dim, vocab, expert, layer, pos, state, conv, _.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import param as pm

# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "sqrelu":  # RWKV channel-mix
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ArchConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": pm.ones((d,), "_")}
    return {"scale": pm.ones((d,), "_"), "bias": pm.zeros((d,), "_")}


def norm_apply(p, x, cfg: ArchConfig, *, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32)
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU-style or 2-matrix)
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.gated_mlp:
        return {
            "wi": pm.dense(ks[0], (d, f), "embed mlp", dtype=dtype),
            "wg": pm.dense(ks[1], (d, f), "embed mlp", dtype=dtype),
            "wo": pm.dense(ks[2], (f, d), "mlp embed", dtype=dtype),
        }
    return {
        "wi": pm.dense(ks[0], (d, f), "embed mlp", dtype=dtype),
        "wo": pm.dense(ks[2], (f, d), "mlp embed", dtype=dtype),
    }


def mlp_apply(p, x, cfg: ArchConfig):
    """x: (..., d) -> (..., d)."""
    act = activation(cfg.act)
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if cfg.gated_mlp:
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = act(h) * g
    else:
        h = act(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embeddings / heads
# ---------------------------------------------------------------------------


def embed_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    p = {
        "tokens": pm.normal(
            rng, (cfg.vocab_size, cfg.d_model), "vocab embed", dtype=dtype
        )
    }
    if cfg.pos_emb == "learned":
        p["pos"] = pm.normal(
            jax.random.fold_in(rng, 1),
            (max(cfg.n_frontend_positions, 1) + 8, cfg.d_model),
            "pos embed",
            std=0.02,
            dtype=dtype,
        )
    return p


def embed_apply(p, tokens, cfg: ArchConfig, *, positions=None):
    x = jnp.take(p["tokens"], tokens, axis=0)
    if cfg.pos_emb == "learned" and positions is not None:
        x = x + jnp.take(p["pos"], positions, axis=0)
    elif cfg.pos_emb == "sinusoidal" and positions is not None:
        x = x + sinusoidal(positions, cfg.d_model).astype(x.dtype)
    return x


def head_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    if cfg.tie_embeddings:
        return {}
    return {
        "w": pm.dense(rng, (cfg.d_model, cfg.vocab_size), "embed vocab",
                      dtype=dtype)
    }


def head_apply(p, x, embed_params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        w = embed_params["tokens"].T  # (d, V)
    else:
        w = p["w"]
    return jnp.einsum("...d,dv->...v", x, w)


def sinusoidal(positions, d_model: int):
    """positions: int array (...,) -> (..., d_model) float32."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """Rotary embedding. x: (B, S, H, dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freq  # (S, half)
        ang = ang[None, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Modality frontends (assignment: stubs fed by precomputed embeddings)
# ---------------------------------------------------------------------------


def frontend_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    """Projection from stub patch/frame embeddings into the backbone."""
    if cfg.frontend is None:
        return {}
    return {
        "proj": pm.dense(rng, (cfg.d_model, cfg.d_model), "embed embed",
                         dtype=dtype)
    }


def frontend_apply(p, embeds, cfg: ArchConfig):
    if cfg.frontend is None:
        return embeds
    return jnp.einsum("...d,de->...e", embeds, p["proj"])

"""Minimal parameter/module convention (flax is not in the environment).

Parameters are nested dicts whose leaves are ``Param`` objects: a jnp array
plus a space-joined string of *logical axis names* (one per dim, "_" for an
unsharded dim). ``init`` functions return wrapped trees; training code calls
``split`` once to obtain (plain-array tree, axes tree) — the axes tree (str
leaves) feeds the sharding engine and is stored in checkpoints so restores
can re-shard onto any mesh.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import tree_util


@tree_util.register_pytree_node_class
class Param:
    """A parameter leaf: array value + logical axes (static aux data)."""

    __slots__ = ("value", "axes")

    def __init__(self, value: jax.Array, axes: str):
        self.value = value
        self.axes = axes

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    def __repr__(self):
        return f"Param({self.value.shape}, {self.value.dtype}, '{self.axes}')"


def _is_param(x: Any) -> bool:
    return isinstance(x, Param)


def split(tree):
    """Wrapped tree -> (plain array tree, axes-string tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_param)
    return values, axes


def wrap(values, axes):
    return jax.tree.map(Param, values, axes)


def validate(values, axes) -> None:
    """Assert axes tree matches values tree and ranks agree."""

    def check(v, a):
        names = a.split() if a else []
        if len(names) != v.ndim:
            raise ValueError(f"axes {a!r} rank != array rank {v.shape}")

    jax.tree.map(check, values, axes)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense(rng, shape, axes: str, *, dtype=jnp.float32, fan_in: int | None = None):
    """Truncated-normal fan-in init (lecun_normal-style)."""
    if fan_in is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    v = std * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)
    return Param(v, axes)


def normal(rng, shape, axes: str, *, std=0.02, dtype=jnp.float32):
    return Param(std * jax.random.normal(rng, shape, dtype), axes)


def zeros(shape, axes: str, *, dtype=jnp.float32):
    return Param(jnp.zeros(shape, dtype), axes)


def ones(shape, axes: str, *, dtype=jnp.float32):
    return Param(jnp.ones(shape, dtype), axes)


def count_params(values) -> int:
    return sum(int(v.size) for v in jax.tree.leaves(values))


def stack_layers(param_trees: list):
    """Stack per-layer wrapped trees along a new leading 'layer' axis.

    Used to build scan-over-layers parameter stacks.
    """

    def stack(*ps):
        axes = ps[0].axes
        return Param(
            jnp.stack([p.value for p in ps]),
            ("layer " + axes).strip(),
        )

    return jax.tree.map(stack, *param_trees, is_leaf=_is_param)

"""Draft-model construction for speculative decoding on the serve path.

Upcycling hands the serving stack a free draft model: the MoE was
initialized by replicating the dense parent's MLP into every expert
(core/upcycle.py), so the dense parent shares tokenizer, embeddings,
attention weights, positions and output-distribution lineage with its
upcycled child. Two zero-training drafts fall out of the checkpoint the
engine already holds:

``dense``
    Extract the dense parent from the MoE params by slicing expert 0 of
    every MoE layer back into a plain MLP and dropping the router. For a
    freshly upcycled checkpoint (``expert_init="copy"``) this IS the
    parent checkpoint bit-for-bit; after fine-tuning it is an expert-0
    truncation — still a valid draft (exact rejection sampling keeps the
    output distribution identical regardless of draft quality; a worse
    draft only lowers the acceptance rate).

``top1``
    Keep the MoE params untouched and truncate routing to ``top_k=1`` —
    the draft shares every weight with the target and just reads fewer
    experts per token.

Both return plain (unwrapped) value trees, matching what ServeEngine
holds after ``param.split``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs import ArchConfig
from repro.models import stack as stk

DRAFT_KINDS = ("none", "dense", "top1")


def dense_parent_params(params, cfg: ArchConfig):
    """Slice the dense parent out of an upcycled MoE param tree.

    params: PLAIN value tree of the MoE model (post ``param.split``).
    Every MoE layer's ``ffn = {router, experts: {wi[, wg], wo}}``
    becomes ``{k: experts[k][0]}`` (expert 0's copy of the parent MLP);
    all other subtrees are shared by reference — no copies, no extra
    host memory beyond the sliced MLPs.

    Returns (dense_params, dense_cfg) with ``dense_cfg =
    cfg.dense_parent()``.
    """
    if cfg.moe is None:
        raise ValueError("config has no MoE section; nothing to slice")
    from repro.core.upcycle import _restack_values, _unstack_values

    dense_cfg = cfg.dense_parent()

    def map_stack(stack_key: str, which: str):
        tdescs = stk.layer_descs(cfg, stack=which)
        ddescs = stk.layer_descs(dense_cfg, stack=which)
        layers = _unstack_values(params[stack_key], tdescs)
        out = []
        for dl, td, dd in zip(layers, tdescs, ddescs):
            new = dict(dl)
            if td.ffn == "moe" and dd.ffn == "dense":
                new["ffn"] = {
                    k: v[0] for k, v in dl["ffn"]["experts"].items()
                }
            out.append(new)
        return _restack_values(out, ddescs)

    out = dict(params)
    out["stack"] = map_stack("stack", "decoder")
    if cfg.structure == "encoder_decoder":
        out["encoder"] = map_stack("encoder", "encoder")
    return out, dense_cfg


def top1_cfg(cfg: ArchConfig) -> ArchConfig:
    """The target architecture with routing truncated to top-1."""
    if cfg.moe is None:
        raise ValueError("config has no MoE section; cannot truncate")
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, top_k=1),
        name=cfg.name + "-top1",
    )


def make_draft(
    params, cfg: ArchConfig, kind: str
) -> Tuple[Optional[dict], Optional[ArchConfig]]:
    """Build (draft_params, draft_cfg) for a ServeConfig.draft kind.

    ``none`` -> (None, None); ``dense`` -> expert-0 parent extraction;
    ``top1`` -> the same params object under a top-1 routing config.
    """
    if kind == "none":
        return None, None
    if kind == "dense":
        return dense_parent_params(params, cfg)
    if kind == "top1":
        return params, top1_cfg(cfg)
    raise ValueError(f"unknown draft kind {kind!r}; want one of "
                     f"{DRAFT_KINDS}")

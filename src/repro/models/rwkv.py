"""RWKV-6 "Finch" blocks (arXiv:2404.05892): time-mix with data-dependent
decay + channel-mix.

Faithfulness notes (DESIGN.md §7): the data-dependent decay LoRA
(w = exp(-exp(w0 + tanh(x @ A) @ B))) and the per-head bonus ``u`` follow
the paper; the 5-way ddlerp token-shift is simplified to per-stream
mu-lerp (RWKV-5 style shift, RWKV-6 decay). The WKV recurrence runs through
repro.kernels.ops.rwkv6 (chunked XLA or the Pallas TPU kernel).

Channel-mix exposes its core 2-matrix sqrelu MLP through the stack's FFN
slot so sparse upcycling applies to it (DESIGN.md §Arch-applicability);
receptance gating and token shift stay per-layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import param as pm

LORA_DIM = 64


def _hk(cfg: ArchConfig):
    K = cfg.ssm.head_size
    H = cfg.d_model // K
    return H, K


def time_mix_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    d = cfg.d_model
    H, K = _hk(cfg)
    ks = jax.random.split(rng, 8)
    # decay base: spread so exp(-exp(w0)) covers slow..fast per channel.
    w0 = -5.0 + 8.0 * (jnp.arange(d) / max(d - 1, 1)) ** 0.7
    return {
        "mu": pm.Param(
            0.5 * jnp.ones((5, d), dtype), "_ embed"
        ),  # lerp for w,k,v,r,g
        "w0": pm.Param(w0.astype(dtype), "embed"),
        "w_lora_a": pm.normal(ks[0], (d, LORA_DIM), "embed _", std=0.02,
                              dtype=dtype),
        "w_lora_b": pm.zeros((LORA_DIM, d), "_ embed", dtype=dtype),
        "wr": pm.dense(ks[1], (d, H, K), "embed heads head_dim", dtype=dtype),
        "wk": pm.dense(ks[2], (d, H, K), "embed heads head_dim", dtype=dtype),
        "wv": pm.dense(ks[3], (d, H, K), "embed heads head_dim", dtype=dtype),
        "wg": pm.dense(ks[4], (d, H, K), "embed heads head_dim", dtype=dtype),
        "u": pm.normal(ks[5], (H, K), "heads head_dim", std=0.02,
                       dtype=dtype),
        "wo": pm.dense(ks[6], (H, K, d), "heads head_dim embed",
                       fan_in=H * K, dtype=dtype),
        "ln_x": {
            "scale": pm.ones((d,), "embed", dtype=dtype),
            "bias": pm.zeros((d,), "embed", dtype=dtype),
        },
    }


def time_mix_cache_init(cfg: ArchConfig, batch: int, *, dtype=jnp.float32):
    H, K = _hk(cfg)
    return {
        "x_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
    }


TIME_MIX_CACHE_AXES = {
    "x_prev": "batch embed",
    "wkv": "batch heads head_dim head_dim",
}


def _shift(x, x_prev):
    """x: (B,T,d); x_prev: (B,d) state or None -> previous-token stream."""
    if x_prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _group_norm(x, scale, bias, H):
    """Per-head groupnorm on (B, T, d)."""
    B, T, d = x.shape
    xh = x.reshape(B, T, H, d // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(B, T, d) * scale + bias).astype(x.dtype)


def time_mix_apply(
    p, x, cfg: ArchConfig, *, cache=None, mode="train", implementation="xla"
):
    """x: (B, T, d) -> (y, new_cache)."""
    from repro.kernels import ops

    H, K = _hk(cfg)
    B, T, d = x.shape
    x_prev = cache["x_prev"] if cache is not None else None
    xs = _shift(x, x_prev)
    xx = xs - x
    xw, xk, xv, xr, xg = (
        x + xx * p["mu"][i] for i in range(5)
    )
    w_raw = p["w0"] + jnp.einsum(
        "btl,ld->btd", jnp.tanh(xw @ p["w_lora_a"]), p["w_lora_b"]
    )
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32)))  # (B,T,d) in (0,1)
    w = w.reshape(B, T, H, K)
    r = jnp.einsum("btd,dhk->bthk", xr, p["wr"])
    k = jnp.einsum("btd,dhk->bthk", xk, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", xv, p["wv"])
    g = jax.nn.silu(jnp.einsum("btd,dhk->bthk", xg, p["wg"]))

    state0 = cache["wkv"] if cache is not None else None
    o, state = ops.rwkv6(
        r, k, v, w, p["u"], initial_state=state0,
        implementation=implementation,
    )  # (B,T,H,K)
    o = _group_norm(
        o.reshape(B, T, d), p["ln_x"]["scale"], p["ln_x"]["bias"], H
    )
    o = o.reshape(B, T, H, K) * g
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"])

    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"x_prev": x[:, -1], "wkv": state}
    return y, new_cache


# ---------------------------------------------------------------------------
# Channel-mix wrapper: token shift + receptance around the (upcyclable) MLP
# ---------------------------------------------------------------------------


def channel_mix_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(rng, 2)
    return {
        "mu_k": pm.Param(0.5 * jnp.ones((d,), dtype), "embed"),
        "mu_r": pm.Param(0.5 * jnp.ones((d,), dtype), "embed"),
        "wr": pm.dense(ks[0], (d, d), "embed embed", dtype=dtype),
    }


def channel_mix_cache_init(cfg: ArchConfig, batch: int, *, dtype=jnp.float32):
    return {"x_prev": jnp.zeros((batch, cfg.d_model), dtype)}


CHANNEL_MIX_CACHE_AXES = {"x_prev": "batch embed"}


def channel_mix_pre(p, x, *, cache=None):
    """Returns (mlp input xk, receptance gate r, new_cache)."""
    x_prev = cache["x_prev"] if cache is not None else None
    xs = _shift(x, x_prev)
    xx = xs - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"]))
    new_cache = {"x_prev": x[:, -1]} if cache is not None else None
    return xk, r, new_cache

"""GQA attention with chunked online-softmax ("flash") compute.

The jnp implementation here is the XLA path used for training/prefill
lowering: memory is O(q_chunk * kv_chunk) per (batch, head) instead of
O(S^2), so the 32k-prefill dry-run memory analysis is meaningful. The
Pallas TPU kernel (repro/kernels/flash_attention.py) implements the same
math with explicit VMEM BlockSpecs; `ops.flash_attention` selects between
them.

Shapes: q (B, Sq, H, dh); k, v (B, Skv, Kh, dh) with H % Kh == 0 (GQA).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import param as pm
from repro.models.layers import rope

NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class MixedMeta:
    """Lane layout of the fused decode + chunked-prefill serve step.

    The mixed step's row batch is ``R = num_decode + num_chunks *
    chunk_tokens`` single-token rows: rows ``[:num_decode]`` are the
    decode lane (one per slot, position = tokens already cached — 0
    marks a free/prefilling slot), the rest are ``num_chunks`` chunk
    lanes of ``chunk_tokens`` consecutive prompt tokens each.
    ``chunk_lens`` (NC,) counts the valid rows per chunk (0 = idle
    lane). Per-row absolute positions travel as ``cache_index`` and
    per-row block tables as ``block_tables`` — this object only adds
    what cannot be derived from them.

    Speculative verify lanes extend the layout to ``R = num_decode +
    num_verify * verify_tokens + num_chunks * chunk_tokens``: rows
    ``[num_decode : num_decode + num_verify * verify_tokens]`` are
    ``num_verify`` verify lanes of ``verify_tokens`` consecutive
    positions each (pending token + k drafted tokens of one slot),
    attention-wise identical to chunk lanes — multi-query rows against
    the slot's block table, each row attending pool positions <= its
    own. ``verify_lens`` (NV,) counts valid rows per lane (0 = slot
    not verifying this tick; its rows scatter to the trash block).
    """

    num_decode: int
    num_chunks: int
    chunk_tokens: int
    chunk_lens: jax.Array  # (num_chunks,) int32
    num_verify: int = 0
    verify_tokens: int = 0
    verify_lens: Optional[jax.Array] = None  # (num_verify,) int32


def attention_init(rng, cfg: ArchConfig, *, dtype=jnp.float32):
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": pm.dense(ks[0], (d, h, dh), "embed heads head_dim", dtype=dtype),
        "wk": pm.dense(ks[1], (d, kh, dh), "embed kv_heads head_dim", dtype=dtype),
        "wv": pm.dense(ks[2], (d, kh, dh), "embed kv_heads head_dim", dtype=dtype),
        "wo": pm.dense(
            ks[3], (h, dh, d), "heads head_dim embed", dtype=dtype,
            fan_in=h * dh,
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = pm.zeros((h, dh), "heads head_dim", dtype=dtype)
        p["bk"] = pm.zeros((kh, dh), "kv_heads head_dim", dtype=dtype)
        p["bv"] = pm.zeros((kh, dh), "kv_heads head_dim", dtype=dtype)
    return p


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention; O(q_chunk*kv_chunk) live scores.

    q_offset: absolute position of q[0] (for causal masking during decode).
    kv_len: number of valid kv positions (cache may be padded).
    """
    B, Sq, H, dh = q.shape
    _, Skv, Kh, _ = k.shape
    G = H // Kh
    scale = dh ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # Pad to chunk multiples (model seq lens are powers of two; padding is a
    # no-op there but keeps odd test shapes working).
    pad_q = (-Sq) % q_chunk
    pad_kv = (-Skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_kv
    if kv_len is None:
        kv_len = jnp.asarray(Skv, jnp.int32)

    # (B, Kh, G, S, dh) grouped-query layout.
    qg = q.reshape(B, Sq_p, Kh, G, dh).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)  # (B, Kh, Skv, dh)
    vg = v.transpose(0, 2, 1, 3)

    nq = Sq_p // q_chunk
    nkv = Skv_p // kv_chunk
    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    qg = qg.reshape(B, Kh, G, nq, q_chunk, dh).transpose(3, 0, 1, 2, 4, 5)
    kg = kg.reshape(B, Kh, nkv, kv_chunk, dh).transpose(2, 0, 1, 3, 4)
    vg = vg.reshape(B, Kh, nkv, kv_chunk, dh).transpose(2, 0, 1, 3, 4)

    def q_block(args):
        qb, iq = args  # qb: (B, Kh, G, qc, dh)
        q_pos = q_pos_base + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, xs):
            m, l, acc = carry
            kb, vb, ikv = xs  # kb: (B, Kh, kc, dh)
            kv_pos = ikv * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bkgqd,bktd->bkgqt", qb, kb,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = kv_pos[None, :] < kv_len  # valid kv
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            else:
                mask = jnp.broadcast_to(mask, (q_chunk, kv_chunk))
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # Rows with no valid key yet keep m == -inf; guard the exp.
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.where(
                jnp.isfinite(m), jnp.exp(m - m_safe), 0.0
            )
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, Kh, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kh, G, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kg, vg, jnp.arange(nkv))
        )
        l = jnp.where(l == 0.0, 1.0, l)
        return acc / l[..., None]

    out = jax.lax.map(q_block, (qg, jnp.arange(nq)))  # (nq,B,Kh,G,qc,dh)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Kh, G, Sq_p, dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq_p, H, dh)
    if pad_q:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def reference_attention(q, k, v, *, causal=True, q_offset=0, kv_len=None):
    """O(S^2)-memory oracle for tests."""
    B, Sq, H, dh = q.shape
    _, Skv, Kh, _ = k.shape
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, dh)
    s = jnp.einsum(
        "bqkgd,btkd->bkgqt", qg, k, preferred_element_type=jnp.float32
    ) * dh ** -0.5
    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask = mask & (kv_pos[None, :] < kv_len)
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgqt,btkd->bqkgd", p, v, preferred_element_type=jnp.float32
    )
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def attention_apply(
    p,
    x,
    cfg: ArchConfig,
    *,
    positions=None,
    causal: bool = True,
    cache=None,
    cache_index=None,
    kv_x=None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    ctx=None,
    pad_heads_multiple: int = 0,
    implementation: str = "xla",
    block_tables=None,
    mixed: Optional[MixedMeta] = None,
):
    """Self- or cross-attention.

    cache: None, or dict {k: (B, S_max, Kh, dh), v: ...} — functional KV
    cache. cache_index: current length (traced int32) where new kv is
    written. kv_x: encoder states for cross-attention (no cache/causality).

    block_tables: None, or (B, nb) int32 — switches the cache to the
    PAGED layout {k: (P, bs, Kh, dh), v: ...} (a global block pool,
    repro/serve): ``cache_index`` becomes the per-slot (B,) int32 length
    vector. Prefill (Sq > 1, one request at a time) writes the prompt's
    k/v into the slot's blocks and attends over the local fresh k/v;
    decode scatters one token per live slot and runs
    ``ops.decode_attention`` (the Pallas paged flash-decode kernel when
    ``implementation="pallas"``, the gather + masked-softmax oracle on
    "xla").

    mixed: None, or a :class:`MixedMeta` — the fused decode + chunked-
    prefill step (``Sq == 1``, rows = decode slots then flattened
    chunks). ``cache_index`` carries PER-ROW absolute positions and
    ``block_tables`` per-row tables; all rows write k/v through ONE
    scatter (``paged_row_write`` — dead rows land in the trash block),
    then the decode lane reads via ``ops.decode_attention`` and the
    chunk lanes via ``ops.prefill_attention`` (the q-tile x kv-block
    paged prefill kernel on "pallas").

    implementation: "xla" | "pallas" | "ref" | "auto" — the flash-attention
    compute path (repro.kernels.ops.flash_attention). "pallas" is fully
    differentiable (custom-VJP backward kernels), so training and prefill
    both run through the fused kernels; single-query decode keeps the
    distributed-softmax path regardless (seq-sharded KV caches).

    pad_heads_multiple: zero-pad query heads (and wo) up to a multiple of
    this, so head counts that don't divide the tensor-parallel mesh axis
    (e.g. qwen2.5's 40 heads on a 16-wide axis) still shard — padded heads
    compute garbage attention that is annihilated by the zero wo rows, so
    the function is EXACTLY preserved (tests/test_attention_padding).
    Returns (y, new_cache).
    """
    from repro.sharding import act as _act

    B, Sq, _ = x.shape
    src = x if kv_x is None else kv_x
    wq, wo = p["wq"], p["wo"]
    H = wq.shape[1]
    Kh = p["wk"].shape[1]
    pad_h = 0
    if pad_heads_multiple and H % pad_heads_multiple:
        # Insert zero heads PER KV GROUP so original heads keep their kv
        # group under the (Kh, G) reshape inside flash attention.
        g0 = H // Kh
        g1 = g0
        while (Kh * g1) % pad_heads_multiple:
            g1 += 1
        pad_h = Kh * g1 - H

        def pad_grouped(w, head_axis):
            shape = w.shape
            w = jnp.moveaxis(w, head_axis, 0).reshape(
                (Kh, g0) + shape[:head_axis] + shape[head_axis + 1:]
            )
            w = jnp.pad(
                w, ((0, 0), (0, g1 - g0)) + ((0, 0),) * (w.ndim - 2)
            )
            w = w.reshape((Kh * g1,) + shape[:head_axis]
                          + shape[head_axis + 1:])
            return jnp.moveaxis(w, 0, head_axis)

        wq = pad_grouped(wq, 1)
        wo = pad_grouped(wo, 0)
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bq" in p:
        bq = p["bq"] if not pad_h else pad_grouped(p["bq"], 0)
        q, k, v = q + bq, k + p["bk"], v + p["bv"]
    q = _act(ctx, q, "batch seq heads head_dim")
    k = _act(ctx, k, "batch seq kv_heads head_dim")
    v = _act(ctx, v, "batch seq kv_heads head_dim")

    if cfg.pos_emb == "rope" and kv_x is None:
        if positions is None:
            base = jnp.asarray(0 if cache_index is None else cache_index)
            # Per-slot cache indices (paged decode) broadcast to (B, Sq).
            if base.ndim:
                positions = base[:, None] + jnp.arange(Sq)[None]
            else:
                positions = base + jnp.arange(Sq)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    q_offset = 0
    kv_len = None
    paged = block_tables is not None and cache is not None and kv_x is None
    if paged and mixed is not None:
        from repro.kernels import ops

        # Fused decode + verify + chunked-prefill step:
        # R = B_dec + NV*K1 + NC*C rows.
        B_dec, NC, C = (
            mixed.num_decode, mixed.num_chunks, mixed.chunk_tokens
        )
        NV, K1 = mixed.num_verify, mixed.verify_tokens
        v0, c0 = B_dec, B_dec + NV * K1
        pool_k, pool_v = cache["k"], cache["v"]
        positions = cache_index  # (R,) absolute write position per row
        live_parts = []
        if B_dec:
            dec_live = positions[:B_dec] > 0
            live_parts.append(dec_live)
        if NV:
            ver_live = (
                jnp.arange(K1)[None, :] < mixed.verify_lens[:, None]
            )  # (NV, K1)
            live_parts.append(ver_live.reshape(-1))
        if NC:
            chunk_live = (
                jnp.arange(C)[None, :] < mixed.chunk_lens[:, None]
            )  # (NC, C)
            live_parts.append(chunk_live.reshape(-1))
        live = jnp.concatenate(live_parts)
        # ONE cache-write path for all lanes: a single per-row scatter.
        new_pk = paged_row_write(pool_k, k, block_tables, positions, live)
        new_pv = paged_row_write(pool_v, v, block_tables, positions, live)
        cache = {"k": new_pk, "v": new_pv}
        ys = []
        if B_dec:
            # Decode lane: live slots attend their fresh token too.
            y_dec = ops.decode_attention(
                q[:B_dec], new_pk, new_pv, block_tables[:B_dec],
                positions[:B_dec] + dec_live,
                implementation=implementation,
            )
            ys.append(y_dec)
        if NV:
            # Verify lanes: K1 rows per slot (pending token + drafts),
            # row j attends pool positions <= start + j — the draft
            # prefix written above plus everything already cached.
            qv = q[v0:c0, 0].reshape(NV, K1, *q.shape[2:])
            vtab = block_tables[v0:c0].reshape(NV, K1, -1)[:, 0]
            vstart = positions[v0:c0].reshape(NV, K1)[:, 0]
            y_v = ops.prefill_attention(
                qv, new_pk, new_pv, vtab, vstart, mixed.verify_lens,
                implementation=implementation,
            )
            ys.append(y_v.reshape(NV * K1, 1, *y_v.shape[2:]))
        if NC:
            # Chunk lanes: rows attend every pool position <= their own
            # — prefix blocks, earlier chunks and the chunk itself
            # (written above) are all just block reads.
            qc = q[c0:, 0].reshape(NC, C, *q.shape[2:])
            ctab = block_tables[c0:].reshape(NC, C, -1)[:, 0]
            cstart = positions[c0:].reshape(NC, C)[:, 0]
            y_ch = ops.prefill_attention(
                qc, new_pk, new_pv, ctab, cstart, mixed.chunk_lens,
                implementation=implementation,
            )
            ys.append(y_ch.reshape(NC * C, 1, *y_ch.shape[2:]))
        y = ys[0] if len(ys) == 1 else jnp.concatenate(ys, axis=0)
        out = jnp.einsum("bshk,hkd->bsd", y, wo)
        return out, cache
    if paged:
        pool_k, pool_v = cache["k"], cache["v"]
        if Sq > 1:
            # Prefill-on-join: one request at a time into its freshly
            # allocated blocks; attention runs over the LOCAL fresh k/v
            # (a fresh sequence — same discipline as the dense prefill).
            if B != 1:
                raise ValueError(
                    "paged prefill admits one request at a time (B == 1)"
                )
            cache = {
                "k": paged_prefill_write(pool_k, k, block_tables),
                "v": paged_prefill_write(pool_v, v, block_tables),
            }
        else:
            lengths = cache_index  # (B,) tokens already cached per slot
            new_pk = paged_decode_write(pool_k, k, block_tables, lengths)
            new_pv = paged_decode_write(pool_v, v, block_tables, lengths)
            cache = {"k": new_pk, "v": new_pv}
            from repro.kernels import ops

            # Live slots attend over their freshly written token too;
            # FREE slots (length 0) stay at length 0 — their write went
            # to the trash block, which is never read, and the kernel's
            # zero-valid-key guard gives them exact-zero outputs.
            y = ops.decode_attention(
                q, new_pk, new_pv, block_tables,
                lengths + (lengths > 0),
                implementation=implementation,
            )
            out = jnp.einsum("bshk,hkd->bsd", y, wo)
            return out, cache
    elif cache is not None and kv_x is None:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1
        )
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1
        )
        cache = {"k": new_k, "v": new_v}
        q_offset = cache_index
        if Sq > 1:
            # Prefill: attend over the LOCAL fresh k/v, not the cache view.
            # The cache is seq-sharded over the `model` axis (decode-optimal
            # layout); chunked flash over that view forces a reshard per
            # (q, kv) tile — the cache write below is ONE reshard per layer
            # instead. Assumes prefill starts from an empty cache
            # (cache_index == 0), which is how prefill() drives it.
            kv_len = None
        else:
            k, v = new_k, new_v
            kv_len = cache_index + Sq

    if pad_h and (q.shape[2] % k.shape[2]) != 0:
        raise ValueError("padded heads must remain a multiple of kv heads")
    if q.shape[1] == 1 and cache is not None:
        # Decode: one query. Direct attention — XLA lowers the reductions
        # over a seq-sharded KV cache to all-reduce (distributed softmax),
        # so 500k caches shard over the `model` axis with no KV gather.
        y = _decode_attention(q, k, v, kv_len)
    else:
        from repro.kernels import ops

        y = ops.flash_attention(
            q, k, v,
            causal=causal and kv_x is None,
            q_offset=q_offset,
            kv_len=kv_len,
            q_chunk=q_chunk,
            kv_chunk=kv_chunk,
            implementation=implementation,
        )
    out = jnp.einsum("bshk,hkd->bsd", y, wo)
    return out, cache


def _decode_attention(q, k, v, kv_len):
    """q: (B, 1, H, dh); k, v: (B, S, Kh, dh). Softmax over all valid S.

    ``kv_len`` may be a scalar (the static-batch engine's shared cache
    index) or a per-slot (B,) vector (the continuous-batching engine's
    ragged lengths; 0 marks a free slot and yields an exact-zero output
    instead of a NaN softmax). This is the oracle the Pallas paged
    decode kernel is validated against (``ops.decode_attention``).
    """
    B, _, H, dh = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, dh)
    s = jnp.einsum(
        "bkgd,btkd->bkgt", qg, k, preferred_element_type=jnp.float32
    ) * dh ** -0.5
    mask = (
        jnp.arange(Skv)[None, :]
        < jnp.reshape(jnp.asarray(kv_len), (-1, 1))
    )  # (B, Skv) or (1, Skv)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    # Zero-valid-key-safe softmax (identical to jax.nn.softmax wherever
    # at least one key is valid).
    m = s.max(axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask[:, None, None, :], jnp.exp(s - m_safe), 0.0)
    l = p.sum(axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    y = jnp.einsum(
        "bkgt,btkd->bkgd", p, v, preferred_element_type=jnp.float32
    )
    return y.reshape(B, 1, H, dh).astype(q.dtype)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


# ---------------------------------------------------------------------------
# paged KV cache (repro/serve continuous-batching engine)
# ---------------------------------------------------------------------------


def init_paged_cache(cfg: ArchConfig, num_blocks: int, block_size: int, *,
                     dtype=jnp.bfloat16):
    """Global KV block pool replacing the dense (B, max_len, ...) cache:
    fixed-size blocks owned by sequence slots via per-slot block tables
    (allocated/freed by repro.serve.BlockPool). Block 0 is the trash
    block free slots write into."""
    shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def paged_prefill_write(pool, kv, block_table):
    """Write a full prompt's k or v into its slot's blocks.

    pool: (P, bs, Kh, dh); kv: (1, S, Kh, dh) with S % bs == 0 (the
    serve engine buckets prompt lengths to block multiples — padded tail
    positions carry garbage that stays masked by the slot length until
    decode overwrites it); block_table: (1, nb), nb >= S // bs.
    """
    bs = pool.shape[1]
    S = kv.shape[1]
    if S % bs:
        raise ValueError(
            f"paged prefill length ({S}) must be a multiple of the "
            f"block size ({bs}); bucket the prompt before prefill"
        )
    nbu = S // bs
    blocks = kv[0].reshape(nbu, bs, *kv.shape[2:]).astype(pool.dtype)
    return pool.at[block_table[0, :nbu]].set(blocks)


def paged_decode_write(pool, kv, block_tables, lengths):
    """Scatter one decode token's k or v per slot into the pool.

    pool: (P, bs, Kh, dh); kv: (B, 1, Kh, dh); block_tables: (B, nb);
    lengths: (B,) write position per slot (the token count already
    cached). Free slots (length 0, all-zero table rows) land in trash
    block 0 — never read.
    """
    P, bs = pool.shape[:2]
    blk = lengths // bs
    off = lengths % bs
    bids = jnp.take_along_axis(block_tables, blk[:, None], axis=1)[:, 0]
    flat = pool.reshape(P * bs, *pool.shape[2:])
    flat = flat.at[bids * bs + off].set(kv[:, 0].astype(pool.dtype))
    return flat.reshape(pool.shape)


def paged_row_write(pool, kv, row_tables, positions, live):
    """Scatter one token per ROW into the pool at its absolute position
    — the single cache-write path of the mixed serve step (decode rows
    AND chunk rows go through this one scatter).

    pool: (P, bs, Kh, dh); kv: (R, 1, Kh, dh); row_tables: (R, nb) each
    row's slot block table; positions: (R,) absolute token position to
    write; live: (R,) bool — dead rows (free slots, padded chunk rows,
    idle chunk lanes) land in trash block 0, which is never read.
    Positions are clamped into the table so padded rows whose nominal
    position runs past the slot's allocation stay in bounds (they are
    dead and routed to trash anyway).
    """
    P, bs = pool.shape[:2]
    nb = row_tables.shape[1]
    blk = jnp.clip(positions // bs, 0, nb - 1)
    bids = jnp.take_along_axis(row_tables, blk[:, None], axis=1)[:, 0]
    bids = jnp.where(live, bids, 0)
    off = jnp.where(live, positions % bs, 0)
    flat = pool.reshape(P * bs, *pool.shape[2:])
    flat = flat.at[bids * bs + off].set(kv[:, 0].astype(pool.dtype))
    return flat.reshape(pool.shape)


CACHE_AXES = {"k": "batch cache_seq kv_heads head_dim",
              "v": "batch cache_seq kv_heads head_dim"}

"""Minimal optimizer framework (optax is not in the environment).

An ``Optimizer`` is (init, update):
    state   = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params  = tree_map(add, params, updates)

State is a plain dict pytree: {"step": i32, "slots": <per-leaf dicts
mirroring the param tree>} — checkpointable with the same store as params,
and structurally mappable by core/upcycle.upcycle_opt_state.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree):
    import jax.numpy as jnp

    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )

"""LR schedules. All return f(step: int32 array) -> float32 lr.

The paper continues the dense checkpoint's inverse-sqrt schedule "where it
left off" (§4.1) — our train state carries the absolute step, so resuming
an upcycled model continues the schedule with no discontinuity by
construction.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def inverse_sqrt(peak: float = 0.01, warmup_steps: int = 10_000):
    """T5 schedule: lr = peak * sqrt(warmup) / sqrt(max(step, warmup))."""

    def f(step):
        s = jnp.maximum(step, warmup_steps).astype(jnp.float32)
        return peak * jnp.sqrt(float(warmup_steps)) / jnp.sqrt(s)

    return f


def rsqrt_with_cooldown(
    peak: float = 4e-4,
    warmup_steps: int = 10_000,
    timescale: int = 100_000,
    cooldown_start: int = 0,
    cooldown_steps: int = 50_000,
):
    """Vision schedule (paper §A.1.2): linear warmup, reverse-sqrt decay
    with a timescale, final linear cooldown to 0."""

    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0)
        decay = jnp.sqrt(
            timescale / jnp.maximum(s + timescale - warmup_steps,
                                    float(timescale))
        )
        lr = peak * warm * decay
        if cooldown_start > 0:
            frac = jnp.clip(
                (s - cooldown_start) / max(cooldown_steps, 1), 0.0, 1.0
            )
            lr = lr * (1.0 - frac)
        return lr

    return f


def cosine(peak: float, total_steps: int, warmup_steps: int = 0,
           floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0) if warmup_steps \
            else 1.0
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        return floor + (peak - floor) * warm * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog)
        )

    return f

"""Adafactor (Shazeer & Stern 2018) — the paper's optimizer (§A.1.1/§A.1.2).

t5x-flavored implementation:
  * factored second moment for rank>=2 leaves (row/col running averages
    over the last two dims; leading dims — scan 'layer' and 'expert' dims —
    are batch dims, which is exactly what makes optimizer-state upcycling
    (§B.6) a broadcast);
  * decay beta2_t = 1 - (t+1)^-0.8;
  * update clipped to RMS threshold d=1.0;
  * optional multiply-by-parameter-scale (T5 pretraining default);
  * optional momentum (off by default — sublinear memory);
  * decoupled weight decay.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def _factored(shape, min_size: int = 128) -> bool:
    """Factor the last two dims only when both are large enough to be worth
    it (optax convention). Crucially this leaves scan-stacked small params
    (e.g. norm scales of shape (layers, d)) UNfactored — factoring across
    the stacked layer dim would couple unrelated layers and break the
    positional optimizer-state upcycling surgery."""
    return len(shape) >= 2 and min(shape[-1], shape[-2]) >= min_size


def adafactor(
    lr: Callable,
    *,
    decay_exponent: float = 0.8,
    clip_threshold: float = 1.0,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    multiply_by_parameter_scale: bool = True,
    beta1: Optional[float] = None,
    weight_decay: float = 0.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    def init(params):
        def slot(p):
            s = {}
            if _factored(p.shape, min_dim_size_to_factor):
                s["v_row"] = jnp.zeros(p.shape[:-1], jnp.float32)
                s["v_col"] = jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32)
            else:
                s["v"] = jnp.zeros(p.shape, jnp.float32)
            if beta1 is not None:
                s["m"] = jnp.zeros(p.shape, jnp.float32)
            return s

        return {
            "step": jnp.zeros((), jnp.int32),
            "slots": jax.tree.map(slot, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        beta2 = 1.0 - jnp.power(step.astype(jnp.float32), -decay_exponent)
        lr_t = lr(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps1
            new_s = dict(s)
            if _factored(g.shape, min_dim_size_to_factor):
                vr = beta2 * s["v_row"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["v_col"] + (1 - beta2) * g2.mean(axis=-2)
                new_s["v_row"], new_s["v_col"] = vr, vc
                # rank-1 reconstruction of 1/sqrt(v)
                row_mean = vr.mean(axis=-1, keepdims=True)
                r = jax.lax.rsqrt(
                    (vr / jnp.maximum(row_mean, eps1))[..., None]
                )
                c = jax.lax.rsqrt(vc)[..., None, :]
                u = g * r * c
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                new_s["v"] = v
                u = g * jax.lax.rsqrt(v)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if beta1 is not None:
                m = beta1 * s["m"] + (1 - beta1) * u
                new_s["m"] = m
                u = m
            scale = lr_t
            if multiply_by_parameter_scale:
                p_rms = jnp.sqrt(
                    jnp.mean(jnp.square(p.astype(jnp.float32)))
                )
                scale = scale * jnp.maximum(p_rms, eps2)
            delta = -scale * u
            if weight_decay:
                delta = delta - lr_t * weight_decay * p.astype(jnp.float32)
            return delta.astype(p.dtype), new_s

        flat = jax.tree.map(
            upd, grads, state["slots"], params,
            is_leaf=lambda x: isinstance(x, jax.Array)
            and not isinstance(x, dict),
        )
        # flat is a tree whose leaves are (delta, slot) tuples at param
        # positions; split them.
        updates = jax.tree.map(
            lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple)
        )
        slots = jax.tree.map(
            lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple)
        )
        return updates, {"step": step, "slots": slots}

    return Optimizer(init, update)

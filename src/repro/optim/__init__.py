from repro.optim.adafactor import adafactor  # noqa: F401
from repro.optim.adamw import adamw, sgd  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine,
    inverse_sqrt,
    rsqrt_with_cooldown,
)

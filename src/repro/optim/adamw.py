"""AdamW + SGD-momentum (modern options; Adafactor is the paper-faithful
default)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adamw(
    lr: Callable,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "slots": jax.tree.map(
                lambda p: {
                    "m": jnp.zeros(p.shape, jnp.float32),
                    "v": jnp.zeros(p.shape, jnp.float32),
                },
                params,
            ),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - jnp.power(b1, t)
        bc2 = 1.0 - jnp.power(b2, t)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * g
            v = b2 * s["v"] + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = -lr_t * (
                mh / (jnp.sqrt(vh) + eps)
                + weight_decay * p.astype(jnp.float32)
            )
            return delta.astype(p.dtype), {"m": m, "v": v}

        flat = jax.tree.map(
            upd, grads, state["slots"], params,
            is_leaf=lambda x: isinstance(x, jax.Array)
            and not isinstance(x, dict),
        )
        updates = jax.tree.map(
            lambda t_: t_[0], flat, is_leaf=lambda x: isinstance(x, tuple)
        )
        slots = jax.tree.map(
            lambda t_: t_[1], flat, is_leaf=lambda x: isinstance(x, tuple)
        )
        return updates, {"step": step, "slots": slots}

    return Optimizer(init, update)


def sgd(lr: Callable, *, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "slots": jax.tree.map(
                lambda p: {"m": jnp.zeros(p.shape, jnp.float32)}, params
            ),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step)

        def upd(g, s):
            m = momentum * s["m"] + g.astype(jnp.float32)
            return (-lr_t * m), {"m": m}

        flat = jax.tree.map(
            upd, grads, state["slots"],
            is_leaf=lambda x: isinstance(x, jax.Array)
            and not isinstance(x, dict),
        )
        updates = jax.tree.map(
            lambda t: t[0].astype(t[0].dtype), flat,
            is_leaf=lambda x: isinstance(x, tuple),
        )
        slots = jax.tree.map(
            lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple)
        )
        return updates, {"step": step, "slots": slots}

    return Optimizer(init, update)
